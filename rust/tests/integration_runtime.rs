//! Runtime integration: the AOT artifacts must compute exactly what the
//! pure-rust references compute. Requires `make artifacts` (tiny config).

use rsq::model::{config::Module, ParamSet};
use rsq::quantref;
use rsq::runtime::{self, Engine};
use rsq::tensor::Tensor;
use rsq::util::Pcg;

fn engine() -> Engine {
    Engine::load("tiny").expect("run `make artifacts` first")
}

#[test]
fn manifest_cross_validates_config() {
    let eng = engine();
    let cfg = eng.config();
    assert_eq!(cfg.name, "tiny");
    assert_eq!(cfg.d, 64);
    assert_eq!(cfg.param_names().len(), eng.manifest.params.len());
}

#[test]
fn embed_matches_host_computation() {
    let eng = engine();
    let cfg = eng.config().clone();
    let p = ParamSet::init(&cfg, 0);
    let tokens: Vec<Vec<i32>> = (0..cfg.batch)
        .map(|b| (0..32).map(|t| ((b * 31 + t * 7) % cfg.vocab) as i32).collect())
        .collect();
    let outs = eng
        .exec(
            "embed_t32",
            &[
                runtime::tokens_literal(&tokens, 32).unwrap(),
                runtime::tensor_literal(&p.tensors[0]).unwrap(),
                runtime::tensor_literal(&p.tensors[1]).unwrap(),
            ],
        )
        .unwrap();
    let z = runtime::literal_tensor(&outs[0]).unwrap();
    assert_eq!(z.shape, vec![cfg.batch, 32, cfg.d]);
    // host check: z[b,t,:] = emb[tok] + pos[t]
    let (emb, pos) = (&p.tensors[0], &p.tensors[1]);
    for b in 0..cfg.batch {
        for t in 0..32 {
            let tok = tokens[b][t] as usize;
            for k in 0..cfg.d {
                let want = emb.at2(tok, k) + pos.at2(t, k);
                let got = z.data[(b * 32 + t) * cfg.d + k];
                assert!((want - got).abs() < 1e-5, "b{b} t{t} k{k}");
            }
        }
    }
}

#[test]
fn hessian_module_matches_reference() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Pcg::new(1);
    let x = Tensor::randn(&[cfg.batch, 32, cfg.d], 1.0, &mut rng);
    let r_rows: Vec<Vec<f32>> = (0..cfg.batch)
        .map(|_| (0..32).map(|_| rng.f32()).collect())
        .collect();
    let r = Tensor::from_vec(&[cfg.batch, 32], r_rows.iter().flatten().cloned().collect());
    let outs = eng
        .exec(
            "hess_d_t32",
            &[runtime::tensor_literal(&x).unwrap(), runtime::tensor_literal(&r).unwrap()],
        )
        .unwrap();
    let h = runtime::literal_tensor(&outs[0]).unwrap();
    // reference
    let mut rows = Vec::new();
    let mut rflat = Vec::new();
    for b in 0..cfg.batch {
        for t in 0..32 {
            rows.push(x.data[(b * 32 + t) * cfg.d..(b * 32 + t + 1) * cfg.d].to_vec());
            rflat.push(r_rows[b][t]);
        }
    }
    let href = quantref::hessian_scaled(&rows, &rflat);
    let scale = href.abs_max().max(1.0);
    assert!(
        h.sub(&href).abs_max() / scale < 1e-4,
        "hessian mismatch {}",
        h.sub(&href).abs_max()
    );
}

#[test]
fn gptq_module_matches_rust_reference() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Pcg::new(2);
    let w = Tensor::randn(&[cfg.d, cfg.d], 0.2, &mut rng);
    // realistic PSD Hessian
    let x: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..cfg.d).map(|_| rng.normal()).collect())
        .collect();
    let h = quantref::hessian_scaled(&x, &vec![1.0; 256]);
    for maxq in [3.0f32, 7.0, 15.0] {
        let outs = eng
            .exec(
                "gptq_64x64",
                &[
                    runtime::tensor_literal(&w).unwrap(),
                    runtime::tensor_literal(&h).unwrap(),
                    runtime::scalar_literal(maxq),
                    runtime::scalar_literal(0.01),
                ],
            )
            .unwrap();
        let q_hlo = runtime::literal_tensor(&outs[0]).unwrap();
        let err_hlo = runtime::literal_scalar(&outs[1]).unwrap();
        let (q_ref, err_ref) = quantref::gptq(&w, &h, maxq, 0.01);
        assert!(
            q_hlo.sub(&q_ref).abs_max() < 1e-4,
            "maxq {maxq}: weight mismatch {}",
            q_hlo.sub(&q_ref).abs_max()
        );
        assert!((err_hlo - err_ref).abs() / err_ref.max(1.0) < 1e-3);
    }
}

#[test]
fn rtn_module_matches_rust_reference() {
    let eng = engine();
    let mut rng = Pcg::new(3);
    let w = Tensor::randn(&[128, 64], 0.3, &mut rng);
    let outs = eng
        .exec(
            "rtn_128x64",
            &[runtime::tensor_literal(&w).unwrap(), runtime::scalar_literal(7.0)],
        )
        .unwrap();
    let q = runtime::literal_tensor(&outs[0]).unwrap();
    let q_ref = quantref::rtn(&w, 7.0);
    assert!(q.sub(&q_ref).abs_max() < 1e-5);
}

#[test]
fn ldlq_module_outputs_codewords() {
    let eng = engine();
    let cfg = eng.config().clone();
    let mut rng = Pcg::new(4);
    let w = Tensor::randn(&[cfg.d, cfg.d], 0.3, &mut rng);
    let x: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..cfg.d).map(|_| rng.normal()).collect())
        .collect();
    let h = quantref::hessian_scaled(&x, &vec![1.0; 256]);
    let cb = rsq::quant::vq::e8_codebook(cfg.ldlq_k, 0);
    let outs = eng
        .exec(
            "ldlq_64x64",
            &[
                runtime::tensor_literal(&w).unwrap(),
                runtime::tensor_literal(&h).unwrap(),
                runtime::tensor_literal(&cb).unwrap(),
                runtime::scalar_literal(0.01),
            ],
        )
        .unwrap();
    let q = runtime::literal_tensor(&outs[0]).unwrap();
    assert_eq!(q.shape, vec![cfg.d, cfg.d]);
    assert!(q.data.iter().all(|v| v.is_finite()));
    // every 8-block of every row must be s * codeword for the row's scale
    for r in 0..4 {
        let wrow = w.row(r);
        let s = (wrow.iter().map(|v| v * v).sum::<f32>() / wrow.len() as f32).sqrt() + 1e-8;
        for b in 0..2 {
            let blk: Vec<f32> = q.row(r)[b * 8..(b + 1) * 8].iter().map(|v| v / s).collect();
            let mut best = f32::INFINITY;
            for ci in 0..cfg.ldlq_k {
                let c = cb.row(ci);
                let d2: f32 = blk.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                best = best.min(d2);
            }
            assert!(best < 1e-6, "row {r} block {b}: nearest codeword d2={best}");
        }
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    let eng = engine();
    // wrong arity
    assert!(eng.exec("rtn_64x64", &[runtime::scalar_literal(7.0)]).is_err());
    // wrong shape
    let w = Tensor::zeros(&[2, 2]);
    assert!(eng
        .exec(
            "rtn_64x64",
            &[runtime::tensor_literal(&w).unwrap(), runtime::scalar_literal(7.0)]
        )
        .is_err());
    // unknown module
    assert!(eng.exec("nope", &[]).is_err());
}

#[test]
fn weight_shape_artifacts_exist_for_all_modules() {
    let eng = engine();
    let cfg = eng.config().clone();
    for m in Module::ALL {
        let (o, i) = cfg.weight_shape(m);
        assert!(eng.manifest.module(&format!("gptq_{o}x{i}")).is_ok());
        assert!(eng.manifest.module(&format!("rtn_{o}x{i}")).is_ok());
        assert!(eng.manifest.module(&format!("ldlq_{o}x{i}")).is_ok());
    }
}
