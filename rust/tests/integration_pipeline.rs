//! Pipeline integration: end-to-end quantization invariants on the tiny
//! config. Requires `make artifacts`.

use std::collections::HashSet;

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::model::config::Module;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::ParamSet;
use rsq::quant::{quantize, Method, QuantOptions, SchedMode, Strategy};
use rsq::runtime::Engine;
use rsq::train::train_or_load;

fn setup() -> (Engine, ParamSet, CalibSet) {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    let cfg = eng.config().clone();
    let (mut p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    inject_outliers(&mut p, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    (eng, p, calib)
}

fn quantized_levels_ok(p: &ParamSet, bits: u32) {
    let maxq = (1usize << bits) - 1;
    for l in 0..p.cfg.layers {
        for m in Module::ALL {
            let w = p.weight(l, m);
            for i in 0..w.rows().min(8) {
                let mut lv: Vec<f32> = w.row(i).to_vec();
                lv.sort_by(f32::total_cmp);
                lv.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
                assert!(
                    lv.len() <= maxq + 1,
                    "layer {l} {m:?} row {i}: {} levels > {}",
                    lv.len(),
                    maxq + 1
                );
            }
        }
    }
}

#[test]
fn every_method_quantizes_every_weight_once() {
    let (eng, p, calib) = setup();
    for method in [Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq, Method::Rsq] {
        let opts = QuantOptions::new(method, 3, 64);
        let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
        assert_eq!(report.layer_err.len(), p.cfg.layers, "{method:?}");
        // every transformer weight changed (quantized exactly once each)
        for l in 0..p.cfg.layers {
            for m in Module::ALL {
                // compare against the appropriate pre-quant reference
                assert!(
                    q.weight(l, m).data.iter().all(|v| v.is_finite()),
                    "{method:?} {l} {m:?} non-finite"
                );
            }
        }
        if !method.vector_quant() {
            quantized_levels_ok(&q, 3);
        }
    }
}

#[test]
fn rotation_changes_embeddings_only_for_rotating_methods() {
    let (eng, p, calib) = setup();
    let (q_gptq, _) =
        quantize(&eng, &p, &calib, &QuantOptions::new(Method::Gptq, 3, 64)).unwrap();
    assert_eq!(q_gptq.tensors[0].data, p.tensors[0].data, "gptq must not touch emb");
    let (q_rsq, _) = quantize(&eng, &p, &calib, &QuantOptions::new(Method::Rsq, 3, 64)).unwrap();
    assert_ne!(q_rsq.tensors[0].data, p.tensors[0].data, "rsq must rotate emb");
}

#[test]
fn rotation_reduces_kurtosis_in_report() {
    let (eng, p, calib) = setup();
    let (_, r) = quantize(&eng, &p, &calib, &QuantOptions::new(Method::Rsq, 3, 64)).unwrap();
    assert!(r.kurtosis_after < r.kurtosis_before, "{r:?}");
    let (_, r2) = quantize(&eng, &p, &calib, &QuantOptions::new(Method::Gptq, 3, 64)).unwrap();
    assert!((r2.kurtosis_after - r2.kurtosis_before).abs() < 1e-6);
}

#[test]
fn chunk_strategy_reduces_chunk_error() {
    // the paper's Sec. 4.1 observation, in miniature: weighting the first
    // chunk reduces reconstruction error on exactly those tokens
    let (eng, p, calib) = setup();
    let uni = QuantOptions {
        strategy: Strategy::Uniform,
        ..QuantOptions::new(Method::Rsq, 3, 64)
    };
    let chunk = QuantOptions {
        strategy: Strategy::Chunk { index: 1, of: 4 },
        ..QuantOptions::new(Method::Rsq, 3, 64)
    };
    let (q_uni, _) = quantize(&eng, &p, &calib, &uni).unwrap();
    let (q_chunk, _) = quantize(&eng, &p, &calib, &chunk).unwrap();
    // both produce valid quantized models; detailed PPL ordering is the
    // domain of the table drivers (stochastic at tiny scale)
    assert_ne!(q_uni.weight(0, Module::Wq).data, q_chunk.weight(0, Module::Wq).data);
}

#[test]
fn expansion_multiplies_batches() {
    let (eng, p, calib) = setup();
    let base = QuantOptions::new(Method::Rsq, 3, 64);
    let (_, r1) = quantize(&eng, &p, &calib, &base).unwrap();
    let expanded = QuantOptions { expansion: 4, ..base };
    let (_, r2) = quantize(&eng, &p, &calib, &expanded).unwrap();
    assert_eq!(r2.batches, r1.batches * 4);
}

#[test]
fn module_mask_restricts_scaling() {
    let (eng, p, calib) = setup();
    let all = QuantOptions::new(Method::Rsq, 3, 64);
    let only_v = QuantOptions {
        module_mask: Some(HashSet::from([Module::Wv])),
        ..all.clone()
    };
    let none = QuantOptions {
        module_mask: Some(HashSet::new()),
        ..all.clone()
    };
    let (q_v, _) = quantize(&eng, &p, &calib, &only_v).unwrap();
    let (q_none, _) = quantize(&eng, &p, &calib, &none).unwrap();
    let (q_uni, _) = quantize(
        &eng,
        &p,
        &calib,
        &QuantOptions { strategy: Strategy::Uniform, ..all },
    )
    .unwrap();
    // empty mask == uniform scaling everywhere
    for l in 0..p.cfg.layers {
        for m in Module::ALL {
            assert!(
                q_none.weight(l, m).allclose(q_uni.weight(l, m), 1e-5),
                "empty mask must equal uniform at {l} {m:?}"
            );
        }
    }
    // masked-v run differs from uniform exactly at wv (and only wv)
    assert!(!q_v.weight(0, Module::Wv).allclose(q_uni.weight(0, Module::Wv), 1e-7));
    assert!(q_v.weight(0, Module::Wq).allclose(q_uni.weight(0, Module::Wq), 1e-5));
}

#[test]
fn vq_methods_produce_finite_weights() {
    let (eng, p, calib) = setup();
    for method in [Method::QuaRotVq, Method::RsqVq] {
        let (q, r) = quantize(&eng, &p, &calib, &QuantOptions::new(method, 2, 64)).unwrap();
        assert!(r.layer_err.iter().all(|e| e.is_finite()));
        for l in 0..p.cfg.layers {
            for m in Module::ALL {
                assert!(q.weight(l, m).data.iter().all(|v| v.is_finite()));
            }
        }
    }
}

#[test]
fn parallel_scheduler_is_bit_identical_to_serial() {
    // the tentpole contract (DESIGN.md §Threading): any --jobs value
    // produces exactly the serial result, bit for bit
    let (eng, p, calib) = setup();
    for method in [Method::Rtn, Method::Rsq, Method::RsqVq] {
        let bits = if method.vector_quant() { 2 } else { 3 };
        let mut o1 = QuantOptions::new(method, bits, 64);
        o1.jobs = 1;
        let mut o4 = o1.clone();
        o4.jobs = 4;
        let (q1, r1) = quantize(&eng, &p, &calib, &o1).unwrap();
        let (q4, r4) = quantize(&eng, &p, &calib, &o4).unwrap();
        assert_eq!(r4.jobs, 4);
        assert_eq!(r1.layer_err, r4.layer_err, "{method:?} layer errors diverged");
        assert_eq!(q1.tensors.len(), q4.tensors.len());
        for (i, (a, b)) in q1.tensors.iter().zip(&q4.tensors).enumerate() {
            assert_eq!(
                a.data, b.data,
                "{method:?} tensor {i}: jobs=4 diverged from jobs=1"
            );
        }
    }
}

#[test]
fn parallel_scheduler_bit_identical_under_partial_module_mask() {
    // the partial-mask path keeps two Hessian accumulators per stream —
    // exercise it too (Fig. 7 ablation + needs_uniform reduction)
    let (eng, p, calib) = setup();
    let mut o1 = QuantOptions {
        module_mask: Some(HashSet::from([Module::Wv, Module::Wdown])),
        ..QuantOptions::new(Method::Rsq, 3, 64)
    };
    o1.jobs = 1;
    let mut o4 = o1.clone();
    o4.jobs = 4;
    let (q1, _) = quantize(&eng, &p, &calib, &o1).unwrap();
    let (q4, _) = quantize(&eng, &p, &calib, &o4).unwrap();
    for (i, (a, b)) in q1.tensors.iter().zip(&q4.tensors).enumerate() {
        assert_eq!(a.data, b.data, "masked tensor {i} diverged");
    }
}

#[test]
fn report_phase_timings_cover_the_run() {
    let (eng, p, calib) = setup();
    let (_, r) = quantize(&eng, &p, &calib, &QuantOptions::new(Method::Rsq, 3, 64)).unwrap();
    assert_eq!(r.jobs, 1);
    assert!(r.pass_a_seconds > 0.0 && r.solve_seconds > 0.0);
    let phases = r.pass_a_seconds + r.solve_seconds + r.pass_b_seconds + r.fused_seconds;
    assert!(
        phases <= r.wall_seconds,
        "phase timings {phases} exceed wall {}",
        r.wall_seconds
    );
    // per-layer timings cover every layer and sum to the process totals
    assert_eq!(r.layer_timings.len(), p.cfg.layers);
    let lsum: f64 = r
        .layer_timings
        .iter()
        .map(|lt| lt.pass_a_seconds + lt.solve_seconds + lt.pass_b_seconds + lt.fused_seconds)
        .sum();
    assert!((lsum - phases).abs() < 1e-9, "layer timings {lsum} != totals {phases}");
    assert!(r.layer_timings.iter().all(|lt| lt.solve_seconds > 0.0));
}

#[test]
fn phase_timing_shape_matches_mode() {
    let (eng, p, calib) = setup();
    let mut staged = QuantOptions::new(Method::Rsq, 3, 64);
    staged.sched = SchedMode::Staged;
    let (_, rs) = quantize(&eng, &p, &calib, &staged).unwrap();
    assert_eq!(rs.sched, "staged");
    assert_eq!(rs.fused_seconds, 0.0, "staged mode never runs fused sweeps");
    assert!(rs.pass_b_seconds > 0.0);
    assert!(rs.layer_timings.iter().all(|lt| lt.pass_a_seconds > 0.0));

    let mut piped = QuantOptions::new(Method::Rsq, 3, 64);
    piped.sched = SchedMode::Pipelined;
    let (_, rp) = quantize(&eng, &p, &calib, &piped).unwrap();
    assert_eq!(rp.sched, "pipelined");
    assert_eq!(rp.pass_b_seconds, 0.0, "pipelined mode fuses every pass B");
    assert!(rp.fused_seconds > 0.0, "needs >= 2 layers on the tiny config");
    // only layer 0 runs a standalone pass A; every non-final layer a fused sweep
    assert!(rp.layer_timings[0].pass_a_seconds > 0.0);
    for (l, lt) in rp.layer_timings.iter().enumerate() {
        if l > 0 {
            assert_eq!(lt.pass_a_seconds, 0.0, "layer {l}");
        }
        if l + 1 < rp.layer_timings.len() {
            assert!(lt.fused_seconds > 0.0, "layer {l}");
        } else {
            assert_eq!(lt.fused_seconds, 0.0, "last layer has no next pass A");
        }
    }
}

#[test]
fn pipelined_executor_bit_identical_to_staged() {
    // the tentpole contract: fusing pass B of layer l with pass A of
    // layer l+1 changes scheduling only — for any jobs value, weights and
    // layer_err match the serial staged path bit for bit
    let (eng, p, calib) = setup();
    for method in [Method::Rsq, Method::Gptq, Method::RsqVq] {
        let bits = if method.vector_quant() { 2 } else { 3 };
        let mut serial = QuantOptions::new(method, bits, 64);
        serial.jobs = 1;
        serial.sched = SchedMode::Staged;
        let (q_ref, r_ref) = quantize(&eng, &p, &calib, &serial).unwrap();
        for jobs in [1usize, 4] {
            let mut o = serial.clone();
            o.jobs = jobs;
            o.sched = SchedMode::Pipelined;
            let (q, r) = quantize(&eng, &p, &calib, &o).unwrap();
            assert_eq!(r.jobs, jobs);
            assert_eq!(
                r_ref.layer_err, r.layer_err,
                "{method:?} jobs={jobs}: layer errors diverged from staged serial"
            );
            for (i, (a, b)) in q_ref.tensors.iter().zip(&q.tensors).enumerate() {
                assert_eq!(
                    a.data, b.data,
                    "{method:?} tensor {i}: pipelined jobs={jobs} diverged from staged jobs=1"
                );
            }
        }
    }
}

#[test]
fn pipelined_executor_bit_identical_under_partial_module_mask() {
    // the partial-mask path carries TWO Hessian accumulators per stream
    // through the fused sweep (Fig. 7) — pin it to the staged serial path
    let (eng, p, calib) = setup();
    let mut serial = QuantOptions {
        module_mask: Some(HashSet::from([Module::Wv, Module::Wdown])),
        ..QuantOptions::new(Method::Rsq, 3, 64)
    };
    serial.jobs = 1;
    serial.sched = SchedMode::Staged;
    let (q_ref, _) = quantize(&eng, &p, &calib, &serial).unwrap();
    for jobs in [1usize, 4] {
        let mut o = serial.clone();
        o.jobs = jobs;
        o.sched = SchedMode::Pipelined;
        let (q, _) = quantize(&eng, &p, &calib, &o).unwrap();
        for (i, (a, b)) in q_ref.tensors.iter().zip(&q.tensors).enumerate() {
            assert_eq!(a.data, b.data, "masked tensor {i} diverged at jobs={jobs}");
        }
    }
}

#[test]
fn bad_seq_len_is_rejected() {
    let (eng, p, calib) = setup();
    let opts = QuantOptions::new(Method::Rsq, 3, 48); // not an artifact length
    assert!(quantize(&eng, &p, &calib, &opts).is_err());
}
