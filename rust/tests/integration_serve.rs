//! Serving-layer integration on the tiny config (requires `make
//! artifacts`): greedy decode through `serve/` on a **packed artifact**
//! must be token-identical to the XLA engine's full-context recompute at
//! every step, for jobs ∈ {1, 4}, batch sizes ∈ {1, 4}, and bits ∈
//! {2, 3, 4, 8} — the DESIGN.md §11 acceptance contract.
//!
//! The engine recompute runs `embed_t32` + the `layer_fwd_t32` chain over
//! the fully decoded sequences, then applies the final RMSNorm + head on
//! the host: causal attention makes position i's hidden state depend only
//! on tokens 0..=i, so one full-context forward checks every decode step
//! at once.

use std::path::PathBuf;

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::model::ParamSet;
use rsq::quant::{artifact, quantize, Method, QuantOptions};
use rsq::runtime::{self, Engine};
use rsq::serve::{serve, PackedModel, ServeOptions, ServeRequest};
use rsq::train::train_or_load;
use rsq::util::Pool;

fn setup() -> (Engine, ParamSet, CalibSet) {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    let cfg = eng.config().clone();
    let (p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    (eng, p, calib)
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rsq_int_serve_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn rmsnorm_gain(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let r = 1.0 / (ss / x.len() as f32 + 1e-6).sqrt();
    x.iter().zip(g).map(|(v, gv)| v * r * gv).collect()
}

/// Per-position greedy argmax of the engine's full-context forward over
/// `seqs` (each of length `t`): embed + layer chain on the engine, final
/// norm + head on the host.
fn engine_stepwise_argmax(
    eng: &Engine,
    params: &ParamSet,
    seqs: &[Vec<i32>],
    t: usize,
) -> Vec<Vec<usize>> {
    let cfg = eng.config().clone();
    let p_lits = params
        .tensors
        .iter()
        .map(runtime::tensor_literal)
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let mut out = Vec::with_capacity(seqs.len());
    let mut i = 0;
    while i < seqs.len() {
        let mut batch: Vec<Vec<i32>> = Vec::with_capacity(cfg.batch);
        for k in 0..cfg.batch {
            batch.push(seqs[(i + k).min(seqs.len() - 1)].clone());
        }
        let tok = runtime::tokens_literal(&batch, t).unwrap();
        let emb_ins = vec![tok, p_lits[0].clone(), p_lits[1].clone()];
        let mut z = eng
            .exec(&format!("embed_t{t}"), &emb_ins)
            .unwrap()
            .into_iter()
            .next()
            .unwrap();
        for l in 0..cfg.layers {
            let mut ins = vec![z];
            for k in 0..9 {
                ins.push(p_lits[2 + l * 9 + k].clone());
            }
            z = eng
                .exec(&format!("layer_fwd_t{t}"), &ins)
                .unwrap()
                .into_iter()
                .next()
                .unwrap();
        }
        let zt = runtime::literal_tensor(&z).unwrap(); // [B, t, d]
        let gf = &params.tensors[params.tensors.len() - 2].data;
        let head = &params.tensors[params.tensors.len() - 1];
        let d = cfg.d;
        let take = cfg.batch.min(seqs.len() - i);
        for b in 0..take {
            let mut rows = Vec::with_capacity(t);
            for pos in 0..t {
                let zrow = &zt.data[(b * t + pos) * d..(b * t + pos + 1) * d];
                let h = rmsnorm_gain(zrow, gf);
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (v, hrow) in (0..cfg.vocab).map(|v| (v, head.row(v))) {
                    let mut dot = 0.0f32;
                    for (a, bx) in h.iter().zip(hrow) {
                        dot += a * bx;
                    }
                    if dot > best_v {
                        best_v = dot;
                        best = v;
                    }
                }
                rows.push(best);
            }
            out.push(rows);
        }
        i += cfg.batch;
    }
    out
}

/// The acceptance sweep: decode on the packed artifact, recompute on the
/// engine, compare every step.
#[test]
fn packed_decode_matches_engine_recompute_every_step() {
    let (eng, p, calib) = setup();
    let t = 32usize;
    let prompt_len = 2usize;
    let max_new = t - prompt_len; // consumed positions stay within t
    for bits in [2u32, 3, 4, 8] {
        let opts = QuantOptions::new(Method::Rsq, bits, t);
        let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
        let dir = tmpdir(&format!("bits{bits}"));
        artifact::save(&dir, &q, &report, &opts).unwrap();
        let (model, manifest) = PackedModel::load(&dir).unwrap();
        assert_eq!(manifest.bits, bits);
        assert!(model.packed_weights() > 0, "bits={bits}: nothing packed");

        let requests: Vec<ServeRequest> = (0..4u64)
            .map(|i| {
                let prompt = calib.samples[i as usize][..prompt_len].to_vec();
                ServeRequest::new(i, prompt, max_new)
            })
            .collect();
        // serve at every (batch, jobs) combination — tokens must agree
        // across all of them (determinism) ...
        let mut decoded: Option<Vec<Vec<i32>>> = None;
        for batch in [1usize, 4] {
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let opts = ServeOptions { max_batch: batch, ..Default::default() };
                let rep = serve(&model, &pool, requests.clone(), &opts).unwrap();
                let toks: Vec<Vec<i32>> =
                    rep.requests.iter().map(|r| r.generated.clone()).collect();
                match &decoded {
                    None => decoded = Some(toks),
                    Some(want) => {
                        assert_eq!(&toks, want, "bits={bits} batch={batch} jobs={jobs}")
                    }
                }
            }
        }
        // ... and against the engine's full-context recompute at every
        // single step
        let decoded = decoded.unwrap();
        let seqs: Vec<Vec<i32>> = requests
            .iter()
            .zip(&decoded)
            .map(|(r, gen)| {
                let mut s = r.prompt.clone();
                s.extend_from_slice(gen);
                assert_eq!(s.len(), t, "bits={bits}");
                s
            })
            .collect();
        let engine_argmax = engine_stepwise_argmax(&eng, &q, &seqs, t);
        for (si, (gen, am)) in decoded.iter().zip(&engine_argmax).enumerate() {
            for (step, &tok) in gen.iter().enumerate() {
                let pos = prompt_len + step - 1;
                assert_eq!(
                    am[pos] as i32, tok,
                    "bits={bits} seq={si} step={step}: serve decode diverged from the \
                     engine's full-context argmax"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// The artifact-loaded serving model and the in-memory quantized set must
/// agree: serving the artifact equals serving the ParamSet it was saved
/// from (load is bit-faithful, so this pins the serve loader too).
#[test]
fn artifact_and_in_memory_models_decode_identically() {
    let (eng, p, calib) = setup();
    let opts = QuantOptions::new(Method::Rsq, 3, 32);
    let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    let dir = tmpdir("inmem");
    artifact::save(&dir, &q, &report, &opts).unwrap();
    let (from_artifact, _) = PackedModel::load(&dir).unwrap();
    let dense = PackedModel::from_paramset_dense(&q).unwrap();
    let prompt = calib.samples[0][..3].to_vec();
    let a = rsq::serve::greedy_decode(&from_artifact, &prompt, 24, None).unwrap();
    let b = rsq::serve::greedy_decode(&dense, &prompt, 24, None).unwrap();
    assert_eq!(a, b, "packed-domain decode != dense decode of the same weights");
    std::fs::remove_dir_all(&dir).ok();
}
