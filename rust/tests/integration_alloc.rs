//! Mixed-precision allocation integration on the tiny config (DESIGN.md
//! §14): the allocator's widths must be invariant across every jobs ×
//! sched combination and across warm-vs-cold Hessian cache runs, the
//! saved artifact must respect the budget, and a mixed-width artifact
//! must round-trip bit-identically through both consumers — `eval
//! --artifact` and the serve/generate packed loader. Requires `make
//! artifacts`.

use std::path::PathBuf;

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::perplexity;
use rsq::model::config::Module;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::ParamSet;
use rsq::quant::{artifact, quantize, BitBudget, Method, QuantOptions, SchedMode};
use rsq::runtime::Engine;
use rsq::train::train_or_load;

fn setup() -> (Engine, ParamSet, CalibSet) {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    let cfg = eng.config().clone();
    let (mut p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    inject_outliers(&mut p, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    (eng, p, calib)
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rsq_int_alloc_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn assert_bit_identical(a: &ParamSet, b: &ParamSet, label: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{label}");
    for (i, (x, y)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(x.shape, y.shape, "{label}: tensor {i} shape");
        for (j, (va, vb)) in x.data.iter().zip(&y.data).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: tensor {i} element {j}: {va} vs {vb}"
            );
        }
    }
}

fn dir_bytes(dir: &PathBuf) -> (Vec<u8>, Vec<u8>) {
    (
        std::fs::read(dir.join(artifact::MANIFEST_FILE)).unwrap(),
        std::fs::read(dir.join(artifact::BLOBS_FILE)).unwrap(),
    )
}

/// The allocation — and the artifact bytes built from it — are a pure
/// function of (weights, calibration, budget): every jobs × sched
/// combination agrees, and a warm cache run (which skips the proxy pass
/// entirely) reproduces the cold run byte-for-byte.
#[test]
fn allocation_is_invariant_across_jobs_sched_and_cache() {
    let (eng, p, calib) = setup();
    let cache_dir = tmpdir("alloc_cache");
    let layers = eng.config().layers;
    let mut baseline: Option<(Vec<u8>, Vec<u8>, Vec<u32>)> = None;
    let mut first = true;
    for jobs in [1usize, 4] {
        for sched in [SchedMode::Staged, SchedMode::Pipelined] {
            let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
            opts.alloc = Some(BitBudget::AvgBits(3.0));
            opts.hess_cache = Some(cache_dir.clone());
            opts.jobs = jobs;
            opts.sched = sched;
            let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
            let label = format!("jobs={jobs} sched={}", sched.name());

            assert_eq!(report.widths.len(), layers * Module::ALL.len(), "{label}");
            let avg = report.avg_bits.expect("allocator runs report avg bits");
            assert!(avg <= 3.0 + 1e-5, "{label}: budget exceeded ({avg} bits)");
            assert!(
                report.widths.iter().all(|w| [2, 3, 4, 8].contains(w)),
                "{label}: widths outside PACK_BITS: {:?}",
                report.widths
            );
            if first {
                assert_eq!(report.hess_cache_misses, layers, "first run is cold");
            } else {
                assert_eq!(report.hess_cache_hits, layers, "{label}: must reuse proxy Hessians");
            }
            first = false;

            let dir = tmpdir(&format!("grid_{jobs}_{}", sched.name()));
            artifact::save(&dir, &q, &report, &opts).unwrap();
            let bytes = dir_bytes(&dir);
            if let Some((man, blob, w0)) = &baseline {
                assert_eq!(&report.widths, w0, "{label}: allocation must be invariant");
                assert_eq!(&bytes.0, man, "{label}: manifest bytes");
                assert_eq!(&bytes.1, blob, "{label}: blob bytes");
            } else {
                baseline = Some((bytes.0, bytes.1, report.widths.clone()));
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    std::fs::remove_dir_all(&cache_dir).ok();
}

/// A mixed-width `--save` artifact records per-slot codecs + provenance
/// and loads bit-identically through the eval path and the serve/generate
/// packed loader.
#[test]
fn mixed_width_artifact_roundtrips_through_eval_and_serve() {
    let (eng, p, calib) = setup();
    let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
    opts.alloc = Some(BitBudget::AvgBits(3.0));
    let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    let dir = tmpdir("roundtrip");
    let manifest = artifact::save(&dir, &q, &report, &opts).unwrap();

    // manifest provenance + per-tensor codecs mirror the allocation
    assert_eq!(manifest.budget.as_deref(), Some("avg-bits:3"));
    assert_eq!(manifest.avg_bits, report.avg_bits);
    let cfg = eng.config();
    for l in 0..cfg.layers {
        for (mi, m) in Module::ALL.into_iter().enumerate() {
            let slot = l * Module::ALL.len() + mi;
            assert_eq!(
                manifest.tensors[cfg.param_index(l, m)].codec,
                artifact::Codec::Packed { bits: report.widths[slot] },
                "layer {l} {m:?} must pack at its allocated width"
            );
        }
    }

    // eval path: bit-identical params, bit-identical perplexity
    let (loaded, _) = artifact::load(&dir).unwrap();
    assert_bit_identical(&loaded, &q, "mixed-width load");
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 2);
    let ppl_mem = perplexity(&eng, &q, &eval, 64).unwrap();
    let ppl_art = perplexity(&eng, &loaded, &eval, 64).unwrap();
    assert_eq!(ppl_mem.to_bits(), ppl_art.to_bits(), "artifact-backed ppl");

    // serve/generate path: the packed loader accepts mixed widths, keeps
    // the provenance, and decodes deterministically
    let (model, m2) = rsq::serve::PackedModel::load(&dir).unwrap();
    assert_eq!(m2.avg_bits, manifest.avg_bits);
    let prompt = vec![1i32, 2, 3, 4];
    let a = rsq::serve::greedy_decode(&model, &prompt, 8, None).unwrap();
    let b = rsq::serve::greedy_decode(&model, &prompt, 8, None).unwrap();
    assert_eq!(a, b, "mixed-width decode is deterministic");
    assert_eq!(a.len(), 8);
    std::fs::remove_dir_all(&dir).ok();
}

/// `--budget-bytes` caps the packed footprint, and the report's
/// accounting equals the bytes actually written to disk.
#[test]
fn budget_bytes_caps_the_packed_footprint() {
    let (eng, p, calib) = setup();
    let cfg = eng.config().clone();
    // a budget exactly equal to the uniform 3-bit footprint: feasible, and
    // tight enough that the allocator has real choices to make
    let budget: u64 = (0..cfg.layers)
        .flat_map(|_| Module::ALL)
        .map(|m| {
            let (o, i) = cfg.weight_shape(m);
            rsq::quant::alloc::packed_weight_bytes(o, i, 3)
        })
        .sum();
    let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
    opts.alloc = Some(BitBudget::Bytes(budget));
    let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    let spent = report.packed_bytes.expect("allocator runs report packed bytes");
    assert!(spent <= budget, "allocator overspent: {spent} > {budget}");

    let dir = tmpdir("bytes");
    let manifest = artifact::save(&dir, &q, &report, &opts).unwrap();
    let on_disk: u64 = manifest
        .tensors
        .iter()
        .filter(|t| matches!(t.codec, artifact::Codec::Packed { .. }))
        .map(|t| t.len)
        .sum();
    assert_eq!(on_disk, spent, "accounting must match the bytes on disk");
    assert_eq!(manifest.budget, Some(format!("budget-bytes:{budget}")));
    std::fs::remove_dir_all(&dir).ok();
}
