//! Golden-file tests for the quantized-artifact format: the fixtures
//! under tests/data/ were produced by an independent implementation
//! (gen_golden_artifact.py) of the v1 layout, pinning the rust loader
//! against concrete bytes — and pinning the failure modes (truncated
//! blob, checksum mismatch, unknown version) to actionable errors, never
//! a panic or silent garbage. Host-only: no compiled artifacts needed.

use std::path::PathBuf;

use rsq::quant::artifact;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name)
}

/// The generator's value formulas, mirrored for assertions.
fn raw_value(tensor_idx: usize, flat_idx: usize) -> f32 {
    (((tensor_idx * 7 + flat_idx * 3) % 31) as f32 - 15.0) * 0.25
}

fn wq_value(r: usize, c: usize) -> f32 {
    let scale = [0.5f32, 0.25, 0.5, 0.25];
    let zero = [2.0f32, 0.0, 1.0, 3.0];
    let code = ((r * 5 + c * 3) % 16) as f32;
    scale[r] * (code - zero[r])
}

#[test]
fn golden_artifact_loads_with_exact_values() {
    let (p, manifest) = artifact::load(&fixture("artifact_ok")).unwrap();
    assert_eq!(manifest.version, 1);
    assert_eq!(manifest.config.name, "golden");
    assert_eq!(manifest.config.d, 4);
    assert_eq!(manifest.method, "rsq");
    assert_eq!(manifest.strategy, "attncon:0.05");
    assert_eq!(manifest.bits, 4);
    assert_eq!(manifest.hess_key, "ab".repeat(16));
    assert_eq!(p.tensors.len(), 13);

    // raw tensors decode the generator's formula exactly
    let emb = &p.tensors[0];
    assert_eq!(emb.shape, vec![16, 4]);
    for i in 0..emb.data.len() {
        assert_eq!(emb.data[i].to_bits(), raw_value(0, i).to_bits(), "emb[{i}]");
    }
    let head = &p.tensors[12];
    for i in 0..head.data.len() {
        assert_eq!(head.data[i].to_bits(), raw_value(12, i).to_bits(), "head[{i}]");
    }

    // the packed tensor dequantizes through the bit-packed path
    let wq = &p.tensors[3];
    assert_eq!(wq.shape, vec![4, 4]);
    assert_eq!(
        manifest.tensors[3].codec,
        artifact::Codec::Packed { bits: 4 },
        "l0.wq is stored packed"
    );
    for r in 0..4 {
        for c in 0..4 {
            assert_eq!(wq.at2(r, c).to_bits(), wq_value(r, c).to_bits(), "wq[{r},{c}]");
        }
    }
    // spot values: code(0,0)=0 -> 0.5*(0-2) = -1.0; code(3,3)=(15+9)%16=8 -> 0.25*(8-3)
    assert_eq!(wq.at2(0, 0), -1.0);
    assert_eq!(wq.at2(3, 3), 1.25);
}

#[test]
fn truncated_blob_is_rejected_with_actionable_error() {
    let err = artifact::load(&fixture("artifact_truncated")).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
    assert!(err.contains("rsq quantize --save"), "error must say how to fix: {err}");
}

#[test]
fn checksum_mismatch_is_rejected_and_names_the_tensor() {
    let err = artifact::load(&fixture("artifact_badsum")).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(err.contains("l0.wq"), "error must name the corrupt tensor: {err}");
}

#[test]
fn unknown_version_is_rejected_with_upgrade_hint() {
    let err = artifact::load(&fixture("artifact_badversion")).unwrap_err().to_string();
    assert!(err.contains("unsupported artifact version 99"), "{err}");
    assert!(err.contains("re-save"), "{err}");
}

#[test]
fn packed_codec_on_one_dim_tensor_is_rejected() {
    // the headline regression: a manifest claiming `packed3` for the 1-D
    // l0.g1 gain used to panic indexing shape[1]; it must be an error
    // that names the tensor and the shape problem
    let err = artifact::load(&fixture("artifact_badshape")).unwrap_err().to_string();
    assert!(err.contains("packed codec on non-matrix shape"), "{err}");
    assert!(err.contains("l0.g1"), "error must name the tensor: {err}");
}

#[test]
fn non_canonical_codec_spelling_is_rejected() {
    // "packed04" parses to the same bits as "packed4" under u32::from_str;
    // the loader must reject it so every codec has exactly one spelling
    let err = artifact::load(&fixture("artifact_badcodec")).unwrap_err().to_string();
    assert!(err.contains("non-canonical codec spelling"), "{err}");
}

#[test]
fn missing_directory_points_at_save() {
    let err = artifact::load(&fixture("no_such_artifact")).unwrap_err().to_string();
    assert!(err.contains("rsq quantize --save"), "{err}");
}

#[test]
fn golden_fixture_survives_a_rust_resave() {
    // load the python-written artifact, re-save it through the rust
    // writer, and confirm a second load sees identical tensors — the two
    // implementations agree on the format in both directions
    let (p, manifest) = artifact::load(&fixture("artifact_ok")).unwrap();
    let dir = std::env::temp_dir().join(format!("rsq_golden_resave_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // rebuild the save-side inputs: grids for the packed tensor come from
    // the manifest-recorded codec via a raw fallback (no grids -> raw)
    let opts = {
        let mut o = rsq::quant::QuantOptions::new(
            rsq::quant::Method::parse(&manifest.method).unwrap(),
            manifest.bits,
            manifest.seq_len,
        );
        o.rot_seed = manifest.rot_seed;
        o
    };
    let report = rsq::quant::QuantReport {
        hess_key: manifest.hess_key.clone(),
        ..Default::default()
    };
    artifact::save(&dir, &p, &report, &opts).unwrap();
    let (p2, _) = artifact::load(&dir).unwrap();
    for (a, b) in p.tensors.iter().zip(&p2.tensors) {
        assert_eq!(a.shape, b.shape);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
