//! Artifact + Hessian-cache integration on the tiny config: `--save` then
//! `eval --artifact` must match the in-memory pipeline bit-for-bit across
//! every jobs × sched combination (incl. the partial module_mask path),
//! and a warm cache must skip pass A while producing byte-identical
//! artifacts. Requires `make artifacts`.

use std::collections::HashSet;
use std::path::PathBuf;

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::perplexity;
use rsq::model::config::Module;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::ParamSet;
use rsq::quant::{artifact, quantize, Method, QuantOptions, SchedMode, Strategy};
use rsq::runtime::Engine;
use rsq::train::train_or_load;

fn setup() -> (Engine, ParamSet, CalibSet) {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    let cfg = eng.config().clone();
    let (mut p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    inject_outliers(&mut p, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    (eng, p, calib)
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rsq_int_artifact_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn assert_bit_identical(a: &ParamSet, b: &ParamSet, label: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{label}");
    for (i, (x, y)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(x.shape, y.shape, "{label}: tensor {i} shape");
        for (j, (va, vb)) in x.data.iter().zip(&y.data).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: tensor {i} element {j}: {va} vs {vb}"
            );
        }
    }
}

fn dir_bytes(dir: &PathBuf) -> (Vec<u8>, Vec<u8>) {
    (
        std::fs::read(dir.join(artifact::MANIFEST_FILE)).unwrap(),
        std::fs::read(dir.join(artifact::BLOBS_FILE)).unwrap(),
    )
}

/// `quantize --save` + `eval --artifact` ≡ the in-memory path, for every
/// jobs × sched combination, and the artifact bytes themselves are
/// invariant across the grid.
#[test]
fn save_then_load_matches_in_memory_across_jobs_and_sched() {
    let (eng, p, calib) = setup();
    let mut baseline: Option<(Vec<u8>, Vec<u8>, ParamSet, f64)> = None;
    for jobs in [1usize, 4] {
        for sched in [SchedMode::Staged, SchedMode::Pipelined] {
            let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
            opts.jobs = jobs;
            opts.sched = sched;
            let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
            let dir = tmpdir(&format!("grid_{jobs}_{}", sched.name()));
            artifact::save(&dir, &q, &report, &opts).unwrap();

            let (loaded, manifest) = artifact::load(&dir).unwrap();
            assert_eq!(manifest.bits, 3);
            assert_eq!(&manifest.config, eng.config());
            assert_bit_identical(&loaded, &q, &format!("jobs={jobs} sched={}", sched.name()));

            // eval through the loaded artifact: logits path == in-memory
            let eval = CalibSet::generate(eng.config().vocab, CorpusKind::Wiki, 8, 64, 7, 2);
            let ppl_mem = perplexity(&eng, &q, &eval, 64).unwrap();
            let ppl_art = perplexity(&eng, &loaded, &eval, 64).unwrap();
            assert_eq!(
                ppl_mem.to_bits(),
                ppl_art.to_bits(),
                "jobs={jobs} sched={}: artifact-backed ppl must be bit-identical",
                sched.name()
            );

            // the artifact bytes are jobs/sched-invariant too
            let bytes = dir_bytes(&dir);
            if let Some((man, blob, q0, ppl0)) = &baseline {
                assert_eq!(&bytes.0, man, "manifest bytes at jobs={jobs} {}", sched.name());
                assert_eq!(&bytes.1, blob, "blob bytes at jobs={jobs} {}", sched.name());
                assert_bit_identical(&q, q0, "cross-scheduler quantized params");
                assert_eq!(ppl_mem.to_bits(), ppl0.to_bits());
            } else {
                baseline = Some((bytes.0, bytes.1, q, ppl_mem));
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// The partial module_mask path keeps both Hessian sets; its artifacts
/// must round-trip bit-identically as well.
#[test]
fn module_mask_artifact_roundtrip() {
    let (eng, p, calib) = setup();
    let mask: HashSet<Module> = [Module::Wq, Module::Wv, Module::Wdown].into_iter().collect();
    for jobs in [1usize, 4] {
        let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
        opts.module_mask = Some(mask.clone());
        opts.jobs = jobs;
        let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
        let dir = tmpdir(&format!("mask_{jobs}"));
        let manifest = artifact::save(&dir, &q, &report, &opts).unwrap();
        assert_eq!(
            manifest.module_mask,
            Some(vec!["wdown".to_string(), "wq".to_string(), "wv".to_string()]),
            "mask is recorded sorted"
        );
        let (loaded, _) = artifact::load(&dir).unwrap();
        assert_bit_identical(&loaded, &q, &format!("module_mask jobs={jobs}"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Second run over a warm cache: pass A skipped (hit counters say so),
/// output params and artifact bytes byte-identical to the cold run — and
/// the hit must survive a jobs/sched change, because the key excludes
/// both.
#[test]
fn warm_hessian_cache_skips_pass_a_and_stays_byte_identical() {
    let (eng, p, calib) = setup();
    let cache_dir = tmpdir("hesscache");
    let layers = eng.config().layers;

    let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
    opts.hess_cache = Some(cache_dir.clone());
    let (q_cold, rep_cold) = quantize(&eng, &p, &calib, &opts).unwrap();
    assert_eq!(rep_cold.hess_cache_hits, 0);
    assert_eq!(rep_cold.hess_cache_misses, layers, "cold run computes + stores");
    assert!(!rep_cold.hess_key.is_empty());

    let d_cold = tmpdir("art_cold");
    artifact::save(&d_cold, &q_cold, &rep_cold, &opts).unwrap();

    // warm, at different jobs AND different sched
    opts.jobs = 4;
    opts.sched = SchedMode::Staged;
    let (q_warm, rep_warm) = quantize(&eng, &p, &calib, &opts).unwrap();
    assert_eq!(rep_warm.hess_cache_hits, layers, "warm run must hit");
    assert_eq!(rep_warm.hess_cache_misses, 0);
    assert_eq!(rep_warm.hess_key, rep_cold.hess_key);
    assert_eq!(rep_warm.pass_a_seconds, 0.0, "pass A skipped");
    assert_eq!(rep_warm.fused_seconds, 0.0, "fused sweeps skipped");
    assert_bit_identical(&q_warm, &q_cold, "warm vs cold params");

    let d_warm = tmpdir("art_warm");
    artifact::save(&d_warm, &q_warm, &rep_warm, &opts).unwrap();
    assert_eq!(dir_bytes(&d_cold), dir_bytes(&d_warm), "artifacts must be byte-identical");

    // different strategy misses (sanity that hits aren't unconditional)
    let mut opts2 = QuantOptions::new(Method::Rsq, 3, 64);
    opts2.hess_cache = Some(cache_dir.clone());
    opts2.strategy = Strategy::ActNorm { r_min: 0.05 };
    let (_, rep2) = quantize(&eng, &p, &calib, &opts2).unwrap();
    assert_eq!(rep2.hess_cache_hits, 0, "different strategy must not hit");
    assert_eq!(rep2.hess_cache_misses, layers);

    for d in [&cache_dir, &d_cold, &d_warm] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Warm hit on the partial-mask path: the uniform Hessian set must
/// survive the store → rehydrate → solve round trip bit-exactly. A bug
/// that dropped or swapped the uniform accumulators on the warm path
/// would quantize the unmasked modules against the wrong Hessians —
/// this is the only end-to-end coverage of that serialization path.
#[test]
fn warm_cache_with_partial_module_mask_is_bit_identical() {
    let (eng, p, calib) = setup();
    let cache_dir = tmpdir("hesscache_mask");
    let layers = eng.config().layers;
    let mask: HashSet<Module> = [Module::Wq, Module::Wdown].into_iter().collect();

    let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
    opts.module_mask = Some(mask);
    opts.hess_cache = Some(cache_dir.clone());
    let (q_cold, rep_cold) = quantize(&eng, &p, &calib, &opts).unwrap();
    assert_eq!(rep_cold.hess_cache_misses, layers);

    opts.jobs = 4;
    let (q_warm, rep_warm) = quantize(&eng, &p, &calib, &opts).unwrap();
    assert_eq!(rep_warm.hess_cache_hits, layers, "masked warm run must hit");
    assert_bit_identical(&q_warm, &q_cold, "warm vs cold under partial mask");

    // and the artifacts built from both are byte-identical
    let (d_cold, d_warm) = (tmpdir("mask_art_cold"), tmpdir("mask_art_warm"));
    artifact::save(&d_cold, &q_cold, &rep_cold, &opts).unwrap();
    artifact::save(&d_warm, &q_warm, &rep_warm, &opts).unwrap();
    assert_eq!(dir_bytes(&d_cold), dir_bytes(&d_warm));
    for d in [&cache_dir, &d_cold, &d_warm] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Uncached runs report skip counters and never touch disk.
#[test]
fn disabled_cache_reports_skips() {
    let (eng, p, calib) = setup();
    let opts = QuantOptions::new(Method::Rsq, 3, 64);
    assert!(opts.hess_cache.is_none());
    let (_, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    assert_eq!(report.hess_cache_hits, 0);
    assert_eq!(report.hess_cache_misses, 0);
    assert_eq!(report.hess_cache_skips, eng.config().layers);
}

/// VQ methods have no affine grid: their artifacts store raw blobs but
/// still round-trip bit-identically.
#[test]
fn vq_artifact_falls_back_to_raw() {
    let (eng, p, calib) = setup();
    let opts = QuantOptions::new(Method::RsqVq, 2, 64);
    let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    let dir = tmpdir("vq");
    let manifest = artifact::save(&dir, &q, &report, &opts).unwrap();
    assert!(
        manifest.tensors.iter().all(|t| matches!(t.codec, artifact::Codec::Raw)),
        "VQ output must store raw"
    );
    let (loaded, _) = artifact::load(&dir).unwrap();
    assert_bit_identical(&loaded, &q, "vq");
    std::fs::remove_dir_all(&dir).ok();
}

/// Non-VQ artifacts actually pack their layer weights (the size win is
/// the point of the codec).
#[test]
fn scalar_artifacts_are_packed_and_smaller() {
    let (eng, p, calib) = setup();
    let opts = QuantOptions::new(Method::Rsq, 3, 64);
    let (q, report) = quantize(&eng, &p, &calib, &opts).unwrap();
    let dir = tmpdir("packed");
    let manifest = artifact::save(&dir, &q, &report, &opts).unwrap();
    let cfg = eng.config();
    let packed = manifest
        .tensors
        .iter()
        .filter(|t| matches!(t.codec, artifact::Codec::Packed { bits: 3 }))
        .count();
    assert_eq!(packed, cfg.layers * Module::ALL.len(), "every layer weight packs");
    let raw_bytes: u64 = manifest
        .tensors
        .iter()
        .filter(|t| matches!(t.codec, artifact::Codec::Packed { .. }))
        .map(|t| 4 * t.shape.iter().product::<usize>() as u64)
        .sum();
    let packed_bytes: u64 = manifest
        .tensors
        .iter()
        .filter(|t| matches!(t.codec, artifact::Codec::Packed { .. }))
        .map(|t| t.len)
        .sum();
    assert!(
        packed_bytes * 2 < raw_bytes,
        "3-bit packing must at least halve the weight bytes ({packed_bytes} vs {raw_bytes})"
    );
    std::fs::remove_dir_all(&dir).ok();
}
