//! Eval-harness integration: perplexity semantics, probe battery, and the
//! long-context suite, all on the tiny config. Requires `make artifacts`.

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::{longctx_suite, perplexity, probe_suite, tasks::mean_accuracy};
use rsq::model::ParamSet;
use rsq::runtime::Engine;
use rsq::train::train_or_load;

fn engine() -> Engine {
    Engine::load("tiny").expect("run `make artifacts` first")
}

#[test]
fn training_lowers_perplexity() {
    let eng = engine();
    let cfg = eng.config().clone();
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 2);
    let random = ParamSet::init(&cfg, 7);
    let ppl_random = perplexity(&eng, &random, &eval, 64).unwrap();
    let (trained, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let ppl_trained = perplexity(&eng, &trained, &eval, 64).unwrap();
    // random init ~ vocab size; trained far below
    assert!(ppl_random > 150.0, "{ppl_random}");
    assert!(ppl_trained < ppl_random * 0.5, "{ppl_trained} vs {ppl_random}");
}

#[test]
fn perplexity_context_length_variants() {
    let eng = engine();
    let cfg = eng.config().clone();
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 2);
    let (p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let p32 = perplexity(&eng, &p, &eval, 32).unwrap();
    let p64 = perplexity(&eng, &p, &eval, 64).unwrap();
    assert!(p32.is_finite() && p64.is_finite());
    // both orders of magnitude sane
    assert!(p32 > 1.0 && p32 < cfg.vocab as f64);
    assert!(p64 > 1.0 && p64 < cfg.vocab as f64);
}

#[test]
fn probe_suite_returns_ten_tasks_in_range() {
    let eng = engine();
    let (p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let results = probe_suite(&eng, &p, 64, 3, 8).unwrap();
    assert_eq!(results.len(), 10);
    let mut names: Vec<&str> = results.iter().map(|r| r.name).collect();
    names.dedup();
    assert_eq!(names.len(), 10, "duplicate task names");
    for r in &results {
        assert!((0.0..=1.0).contains(&r.accuracy), "{r:?}");
        assert_eq!(r.n, 8);
    }
    let avg = mean_accuracy(&results);
    assert!((0.0..=1.0).contains(&avg));
}

#[test]
fn probe_suite_deterministic_for_seed() {
    let eng = engine();
    let (p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let a = probe_suite(&eng, &p, 64, 5, 8).unwrap();
    let b = probe_suite(&eng, &p, 64, 5, 8).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.accuracy, y.accuracy, "{}", x.name);
    }
}

#[test]
fn longctx_suite_shapes() {
    let eng = engine();
    let (p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    let results = longctx_suite(&eng, &p, 64, 3, 8).unwrap();
    assert_eq!(results.len(), 9); // 3 kv levels + 3 needle positions + 2 icl + code
    for r in &results {
        assert!((0.0..=1.0).contains(&r.score), "{r:?}");
    }
    // kv levels are distinct task names
    let kv: Vec<&str> = results
        .iter()
        .filter(|r| r.name.starts_with("kv_retrieval"))
        .map(|r| r.name.as_str())
        .collect();
    assert_eq!(kv.len(), 3);
}
