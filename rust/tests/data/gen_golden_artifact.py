#!/usr/bin/env python3
"""Regenerate the golden quantized-artifact fixtures under rust/tests/data/.

Mirrors the v1 on-disk format of rust/src/quant/artifact/format.rs
(DESIGN.md §9) for a tiny standalone "golden" config, so the rust loader
can be pinned against bytes produced by an independent implementation:

  artifact_ok/          valid artifact: 12 raw tensors + l0.wq bit-packed
  artifact_truncated/   weights.bin cut short -> "truncated" error
  artifact_badsum/      one blob byte flipped  -> "checksum mismatch" error
  artifact_badversion/  version=99             -> "unsupported ... version"
  artifact_badshape/    packed3 claimed for 1-D l0.g1 -> "non-matrix shape"
  artifact_badcodec/    codec=packed04 spelling -> "non-canonical" error

Deterministic by construction (no RNG, no timestamps): re-running it must
reproduce the committed files byte-for-byte.
"""
import os
import struct
import zlib

HERE = os.path.dirname(os.path.abspath(__file__))

CONFIG = dict(config="golden", d=4, layers=1, heads=1, ff=8, vocab=16,
              max_seq=8, batch=2, seq_lens="8", ldlq_k=16, ldlq_g=2)

# (name, shape) in the rust param_names() order for layers=1
PARAMS = [
    ("emb", (16, 4)), ("pos", (8, 4)),
    ("l0.g1", (4,)), ("l0.wq", (4, 4)), ("l0.wk", (4, 4)), ("l0.wv", (4, 4)),
    ("l0.wo", (4, 4)), ("l0.g2", (4,)), ("l0.wup", (8, 4)), ("l0.wgate", (8, 4)),
    ("l0.wdown", (4, 8)), ("gf", (4,)), ("head", (16, 4)),
]

PACKED = "l0.wq"
BITS = 4
SCALE = [0.5, 0.25, 0.5, 0.25]
ZERO = [2.0, 0.0, 1.0, 3.0]


def raw_value(tensor_idx, flat_idx):
    # multiples of 0.25 are exact in f32
    return ((tensor_idx * 7 + flat_idx * 3) % 31 - 15) * 0.25


def code(r, c):
    return (r * 5 + c * 3) % 16


def pack_blob():
    out = b"".join(struct.pack("<f", s) for s in SCALE)
    out += b"".join(struct.pack("<f", z) for z in ZERO)
    rows = []
    for r in range(4):
        # 4 cols x 4 bits = 2 bytes, codes LSB-first
        row = bytearray(2)
        for c in range(4):
            q = code(r, c)
            start = c * BITS
            for k in range(BITS):
                bit = start + k
                if (q >> k) & 1:
                    row[bit // 8] |= 1 << (bit % 8)
        rows.append(bytes(row))
    return out + b"".join(rows)


def raw_blob(tensor_idx, shape):
    n = 1
    for d in shape:
        n *= d
    return b"".join(struct.pack("<f", raw_value(tensor_idx, i)) for i in range(n))


def build():
    blobs = b""
    lines = []
    for idx, (name, shape) in enumerate(PARAMS):
        if name == PACKED:
            blob, codec = pack_blob(), f"packed{BITS}"
        else:
            blob, codec = raw_blob(idx, shape), "raw"
        lines.append(
            f"tensor={name}|codec={codec}|shape={'x'.join(map(str, shape))}"
            f"|offset={len(blobs)}|len={len(blob)}|crc={zlib.crc32(blob):08x}"
        )
        blobs += blob

    manifest = ["format=rsq-artifact", "version=1"]
    manifest += [f"{k}={v}" for k, v in CONFIG.items()]
    manifest += [
        "method=rsq", "strategy=attncon:0.05", "bits=4", "damp=0.01",
        "rot_seed=20823", "seq_len=8", "expansion=1", "module_mask=all",
        "hess_key=" + "ab" * 16,
    ]
    manifest += lines
    manifest.append(f"total_len={len(blobs)}")
    return "\n".join(manifest) + "\n", blobs


def write(dirname, manifest, blobs):
    d = os.path.join(HERE, dirname)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "artifact.txt"), "w") as f:
        f.write(manifest)
    with open(os.path.join(d, "weights.bin"), "wb") as f:
        f.write(blobs)


def main():
    manifest, blobs = build()
    write("artifact_ok", manifest, blobs)
    write("artifact_truncated", manifest, blobs[:-5])
    bad = bytearray(blobs)
    # flip a bit inside l0.wq's packed blob (offset of tensor idx 3)
    wq_off = sum(len(raw_blob(i, s)) for i, (n, s) in enumerate(PARAMS[:2]))
    wq_off += len(raw_blob(2, (4,)))
    bad[wq_off + 3] ^= 0x20
    write("artifact_badsum", manifest, bytes(bad))
    write("artifact_badversion", manifest.replace("version=1", "version=99", 1), blobs)
    # a packed codec claimed for the 1-D l0.g1 gain: the loader must reject
    # it ("packed codec on non-matrix shape"), never index shape[1]
    write("artifact_badshape",
          manifest.replace("tensor=l0.g1|codec=raw|", "tensor=l0.g1|codec=packed3|", 1),
          blobs)
    # a non-canonical codec spelling ("packed04"): parse/render must stay a
    # strict inverse, so one codec never has two on-disk spellings
    write("artifact_badcodec", manifest.replace("codec=packed4", "codec=packed04", 1), blobs)
    print("golden artifact fixtures written under", HERE)


if __name__ == "__main__":
    main()
