//! KV-codec property tests (DESIGN.md §12) — the codec-level half of the
//! quantized-KV test layer (`prop_serve.rs` holds the serving-level
//! half):
//!
//! - the 8-bit linear codec's round-trip error is bounded by the per-row
//!   step for arbitrary finite rows, and all-zero / single-element /
//!   constant rows decode **exactly**;
//! - the 2-bit log codec is sign-correct, monotone in magnitude, and
//!   idempotent (encode∘decode∘encode is a fixed point);
//! - non-finite inputs are clamped deterministically, never written as
//!   garbage codes, and always decode to finite values;
//! - ragged head dims and partial final pages round-trip through
//!   [`SeqKv`] at every format.
//!
//! [`SeqKv`]: rsq::serve::SeqKv

use rsq::serve::kvq::{decode_row, encode_row, RowSource};
use rsq::serve::{KvFormat, SeqKv, KV_BITS};
use rsq::util::Pcg;

/// Row lengths that straddle the code-byte boundaries of both lossy
/// widths (8-bit: 1 code/byte; 2-bit: 4 codes/byte) — ragged head dims.
const DIMS: [usize; 8] = [1, 2, 3, 5, 8, 16, 31, 33];

fn roundtrip(fmt: KvFormat, src: &[f32]) -> Vec<f32> {
    let mut codes = vec![0u8; fmt.row_code_bytes(src.len())];
    let (s0, s1) = encode_row(fmt, src, &mut codes);
    let mut out = vec![0.0f32; src.len()];
    decode_row(fmt, &codes, s0, s1, &mut out);
    out
}

fn random_row(d: usize, scale: f32, rng: &mut Pcg) -> Vec<f32> {
    (0..d).map(|_| rng.normal() * scale).collect()
}

#[test]
fn linear8_roundtrip_error_bounded_by_per_row_step() {
    let mut rng = Pcg::new(61);
    for d in DIMS {
        for scale in [1e-3f32, 1.0, 1e3, 1e30] {
            for _ in 0..20 {
                let src = random_row(d, scale, &mut rng);
                let (lo, hi) = src.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
                    (l.min(v), h.max(v))
                });
                let step = hi / 255.0 - lo / 255.0;
                let out = roundtrip(KvFormat::Linear8, &src);
                // half a step of quantization error plus float slack
                // proportional to the row's magnitude
                let bound = 0.5 * step + 1e-5 * lo.abs().max(hi.abs());
                for (g, &w) in out.iter().zip(&src) {
                    assert!(g.is_finite(), "d={d} scale={scale}");
                    assert!(
                        (g - w).abs() <= bound,
                        "d={d} scale={scale}: |{g} - {w}| > {bound} (step {step})"
                    );
                }
            }
        }
    }
}

#[test]
fn linear8_degenerate_rows_decode_exactly() {
    // all-zero, single-element, and constant rows have step == 0: every
    // code is 0 and decode returns the row value bit-for-bit
    let mut cases: Vec<Vec<f32>> = vec![
        vec![0.0; 7],
        vec![42.5],
        vec![-1e-20],
        vec![-3.25; 33],
        vec![f32::MAX; 3],
    ];
    cases.push(vec![1e30, 1e30, 1e30]);
    for src in cases {
        let out = roundtrip(KvFormat::Linear8, &src);
        for (g, w) in out.iter().zip(&src) {
            assert_eq!(g.to_bits(), w.to_bits(), "constant row {src:?} must be exact");
        }
    }
}

#[test]
fn linear8_extreme_span_does_not_overflow_the_step() {
    // hi - lo overflows f32; hi/255 - lo/255 must not
    let src = [f32::MAX, -f32::MAX, 0.0];
    let out = roundtrip(KvFormat::Linear8, &src);
    for g in &out {
        assert!(g.is_finite(), "decode must stay finite: {out:?}");
    }
    assert_eq!(out[0], f32::MAX, "span max takes the top code exactly");
    assert_eq!(out[1], -f32::MAX, "span min takes the bottom code exactly");
}

#[test]
fn log2_sign_correct_and_monotone_in_magnitude() {
    let mut rng = Pcg::new(62);
    for d in DIMS {
        for scale in [1e-3f32, 1.0, 1e3] {
            for _ in 0..20 {
                let src = random_row(d, scale, &mut rng);
                let out = roundtrip(KvFormat::Log2, &src);
                for (g, &w) in out.iter().zip(&src) {
                    if w != 0.0 {
                        assert_eq!(
                            g.is_sign_negative(),
                            w.is_sign_negative(),
                            "sign must survive: {w} -> {g}"
                        );
                    }
                }
                for i in 0..d {
                    for j in 0..d {
                        if src[i].abs() <= src[j].abs() {
                            assert!(
                                out[i].abs() <= out[j].abs(),
                                "|{}| <= |{}| but |{}| > |{}|",
                                src[i],
                                src[j],
                                out[i],
                                out[j]
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn log2_roundtrip_is_idempotent() {
    let mut rng = Pcg::new(63);
    let mut rows: Vec<Vec<f32>> =
        (0..40).map(|i| random_row(DIMS[i % DIMS.len()], 2.0, &mut rng)).collect();
    // denormal edge: 0.25·M and 0.5·M collapse toward zero, where only
    // the strict level threshold keeps the fixed point
    rows.push(vec![1e-45, -1e-45, 3e-45, 0.0]);
    rows.push(vec![f32::MIN_POSITIVE, -f32::MIN_POSITIVE / 2.0]);
    rows.push(vec![0.0; 5]);
    for src in rows {
        let once = roundtrip(KvFormat::Log2, &src);
        let twice = roundtrip(KvFormat::Log2, &once);
        // f32 equality (not to_bits): a -0.25·M that underflows to -0.0
        // legitimately re-encodes as +0.0
        assert_eq!(twice, once, "encode∘decode∘encode must be a fixed point for {src:?}");
    }
}

#[test]
fn non_finite_inputs_clamp_deterministically() {
    for fmt in [KvFormat::Linear8, KvFormat::Log2] {
        let src = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0, -4.0, 0.5];
        let a = roundtrip(fmt, &src);
        let b = roundtrip(fmt, &src);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.is_finite(), "{fmt:?}: non-finite input decoded non-finite: {a:?}");
            assert_eq!(x.to_bits(), y.to_bits(), "{fmt:?}: clamping must be deterministic");
        }
        // row statistics come from finite elements only: ±inf clamps to
        // the finite span's ends, NaN to the smallest code
        let (lo, hi) = (-4.0f32, 2.0f32);
        match fmt {
            KvFormat::Linear8 => {
                assert_eq!(a[1], hi, "+inf clamps to the row max");
                assert_eq!(a[2], lo, "-inf clamps to the row min");
                assert_eq!(a[0], lo, "NaN clamps to the bottom code");
            }
            KvFormat::Log2 => {
                assert_eq!(a[1], 4.0, "+inf clamps to +M");
                assert_eq!(a[2], -4.0, "-inf clamps to -M");
                assert_eq!(a[0], 1.0, "NaN takes the smallest positive level");
            }
            KvFormat::F32 => unreachable!(),
        }
    }
}

#[test]
fn all_nonfinite_row_decodes_to_exact_zero() {
    for fmt in [KvFormat::Linear8, KvFormat::Log2] {
        for src in [vec![f32::NAN; 4], vec![f32::INFINITY, f32::NEG_INFINITY, f32::NAN]] {
            let out = roundtrip(fmt, &src);
            for g in &out {
                assert_eq!(g.to_bits(), 0.0f32.to_bits(), "{fmt:?} {src:?} -> {out:?}");
            }
        }
    }
}

#[test]
fn ragged_dims_and_partial_final_pages_round_trip_through_seqkv() {
    let mut rng = Pcg::new(64);
    for bits in KV_BITS {
        let fmt = KvFormat::from_bits(bits).unwrap();
        for d in [1usize, 3, 5, 33] {
            // capacity 20 = one full 16-position page + a partial one
            let cap = 20usize;
            let mut kv = SeqKv::standalone_fmt(fmt, 2, d, cap);
            let rows: Vec<Vec<f32>> = (0..cap).map(|_| random_row(d, 1.0, &mut rng)).collect();
            for (pos, row) in rows.iter().enumerate() {
                kv.write(0, pos, row, row);
                kv.write(1, pos, row, row);
            }
            let mut scratch = vec![0.0f32; d];
            for (pos, row) in rows.iter().enumerate() {
                for layer in 0..2 {
                    let got = kv.k_rows(layer).row(pos, &mut scratch).to_vec();
                    let maxabs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    for (g, w) in got.iter().zip(row) {
                        assert!(g.is_finite());
                        let bound = if fmt.is_exact() { 0.0 } else { maxabs };
                        assert!(
                            (g - w).abs() <= bound,
                            "bits={bits} d={d} pos={pos}: {g} vs {w}"
                        );
                    }
                    let again = kv.v_rows(layer).row(pos, &mut scratch).to_vec();
                    assert_eq!(got, again, "k and v were written the same row");
                }
            }
        }
    }
}
