//! Serving-layer property tests (DESIGN.md §11) — all host-side, no AOT
//! artifacts required:
//!
//! - the fused dequantize kernels are **exactly** equal (bitwise, no
//!   tolerance) to `unpack()` + `gemm_bt` over every supported bit width,
//!   ragged and degenerate shapes, and jobs ∈ {1, 4};
//! - `PackedRows::unpack(Some(pool))` is bit-identical to the serial
//!   decode;
//! - greedy KV-cache decode is token-identical to the full-context
//!   matrix recompute at every step (and the final position's log-probs
//!   are bit-identical);
//! - continuous batching returns exactly the solo-decode tokens for
//!   every (batch, jobs) combination, under page-pool pressure, and
//!   surfaces missed deadlines.

use rsq::model::config::ModelConfig;
use rsq::model::ParamSet;
use rsq::quantref;
use rsq::serve::{greedy_decode, serve, PackedModel, ServeOptions, ServeRequest};
use rsq::tensor::kernels::{deq_gemm_bt, deq_gemv, gemm_bt};
use rsq::tensor::pack::{PackedRows, RowGrid, PACK_BITS};
use rsq::tensor::Tensor;
use rsq::util::{Pcg, Pool};

/// RTN-quantize a random [rows, cols] matrix so it packs exactly.
fn packed(rows: usize, cols: usize, bits: u32, rng: &mut Pcg) -> PackedRows {
    let w = Tensor::randn(&[rows, cols], 1.0, rng);
    let maxq = ((1u64 << bits) - 1) as f32;
    let q = quantref::rtn(&w, maxq);
    let (scale, zero) = quantref::row_grid(&w, maxq);
    PackedRows::pack(&q, bits, &RowGrid { scale, zero }).unwrap()
}

/// Activations with exact zeros sprinkled in so the zero-skip path stays
/// live (the §10 contract the fused kernels must reproduce).
fn acts(m: usize, k: usize, rng: &mut Pcg) -> Tensor {
    let data = (0..m * k)
        .map(|_| if rng.f32() < 0.2 { 0.0 } else { rng.normal() })
        .collect();
    Tensor::from_vec(&[m, k], data)
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}");
    for (x, y) in a.data.iter().zip(&b.data) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}");
    }
}

#[test]
fn fused_kernels_match_unpack_gemm_exactly() {
    let mut rng = Pcg::new(31);
    // ragged shapes: widths that straddle byte boundaries for every bit
    // width, single rows/cols, and a tile-crossing k (> 256)
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (1, 7, 5),
        (3, 19, 33),
        (4, 64, 16),
        (2, 300, 11),
        (5, 37, 1),
    ] {
        let a = acts(m, k, &mut rng);
        for bits in PACK_BITS {
            let w = packed(n, k, bits, &mut rng);
            let want = gemm_bt(&a, &w.unpack(None), None);
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let pooled_ref = gemm_bt(&a, &w.unpack(Some(&pool)), Some(&pool));
                assert_bits_eq(&pooled_ref, &want, "reference jobs-invariance");
                for p in [None, Some(&pool)] {
                    let got = deq_gemm_bt(&a, &w, p);
                    let what = format!("deq_gemm_bt {m}x{k}x{n} bits={bits} jobs={jobs}");
                    assert_bits_eq(&got, &want, &what);
                    for i in 0..m {
                        let gv = deq_gemv(a.row(i), &w, p);
                        assert_eq!(gv, want.row(i), "deq_gemv row {i} bits={bits} jobs={jobs}");
                    }
                }
            }
        }
    }
}

#[test]
fn fused_kernels_degenerate_shapes() {
    let mut rng = Pcg::new(32);
    for bits in PACK_BITS {
        // empty activation batch
        let w = packed(6, 9, bits, &mut rng);
        let empty = Tensor::zeros(&[0, 9]);
        let out = deq_gemm_bt(&empty, &w, None);
        assert_eq!(out.shape, vec![0, 6]);
        // all-zero activations: zero-skip leaves exact +0.0 everywhere
        let zeros = Tensor::zeros(&[2, 9]);
        let out = deq_gemm_bt(&zeros, &w, Some(&Pool::new(4)));
        assert_eq!(out.data, vec![0.0; 12]);
        assert_bits_eq(&out, &gemm_bt(&zeros, &w.unpack(None), None), "zero acts");
    }
}

#[test]
fn unpack_is_pool_invariant_across_bits_and_ragged_shapes() {
    let mut rng = Pcg::new(33);
    for (rows, cols) in [(1usize, 1usize), (3, 5), (17, 31), (40, 65)] {
        for bits in PACK_BITS {
            let w = packed(rows, cols, bits, &mut rng);
            let serial = w.unpack(None);
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let what = format!("{rows}x{cols}@{bits}b j{jobs}");
                assert_bits_eq(&w.unpack(Some(&pool)), &serial, &what);
            }
        }
    }
}

fn host_cfg() -> ModelConfig {
    ModelConfig {
        name: "prop-serve".into(),
        d: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        max_seq: 40,
        batch: 2,
        seq_lens: vec![8, 40],
        ldlq_k: 64,
        ldlq_g: 4,
    }
}

#[test]
fn kv_decode_token_identical_to_full_context_recompute() {
    let p = ParamSet::init(&host_cfg(), 41);
    let prompt = [5i32, 9, 2, 14];
    for bits in PACK_BITS {
        let model = PackedModel::from_paramset_rtn(&p, bits).unwrap();
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let gen = greedy_decode(&model, &prompt, 20, Some(&pool)).unwrap();
            assert_eq!(gen.len(), 20, "bits={bits}");
            let mut seq = prompt.to_vec();
            seq.extend_from_slice(&gen);
            // full-context matrix recompute over the whole decoded
            // sequence: causality makes row i equal a fresh forward over
            // tokens 0..=i, so this checks EVERY decode step at once
            let full = model.logits_full(&seq, Some(&pool));
            for (step, &tok) in gen.iter().enumerate() {
                let row = full.row(prompt.len() + step - 1);
                assert_eq!(
                    rsq::eval::argmax(row) as i32,
                    tok,
                    "bits={bits} jobs={jobs} step={step}: KV decode diverged from recompute"
                );
            }
        }
    }
}

#[test]
fn batched_serving_equals_solo_decode_and_is_jobs_invariant() {
    let p = ParamSet::init(&host_cfg(), 42);
    let model = PackedModel::from_paramset_rtn(&p, 3).unwrap();
    let requests: Vec<ServeRequest> = (0..6u64)
        .map(|i| ServeRequest::new(i, vec![(i as i32) % 11 + 1, 3, 7], 5 + (i as usize) % 4))
        .collect();
    let solo: Vec<Vec<i32>> = requests
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, None).unwrap())
        .collect();
    for batch in [1usize, 4] {
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let opts = ServeOptions { max_batch: batch, ..Default::default() };
            let rep = serve(&model, &pool, requests.clone(), &opts).unwrap();
            assert_eq!(rep.requests.len(), requests.len());
            assert!(rep.peak_active <= batch);
            assert!(rep.tokens_per_s > 0.0);
            for (r, want) in rep.requests.iter().zip(&solo) {
                assert_eq!(&r.generated, want, "id={} batch={batch} jobs={jobs}", r.id);
                assert!(!r.deadline_missed);
            }
        }
    }
}

#[test]
fn page_pool_pressure_admits_mid_flight_without_changing_tokens() {
    let p = ParamSet::init(&host_cfg(), 43);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let requests: Vec<ServeRequest> =
        (0..5u64).map(|i| ServeRequest::new(i, vec![1, 2, (i as i32) + 3], 8)).collect();
    let solo: Vec<Vec<i32>> = requests
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, None).unwrap())
        .collect();
    // pool sized for exactly one worst-case reservation: admissions must
    // serialize through retire-and-release, and tokens must not change
    let probe = rsq::serve::PagePool::new(model.cfg.layers, model.cfg.d, 0, 0);
    let pages = probe.pages_for(3 + 8);
    let opts = ServeOptions { max_batch: 4, page: 0, pages };
    let rep = serve(&model, &Pool::new(2), requests, &opts).unwrap();
    assert_eq!(rep.peak_active, 1);
    for (r, want) in rep.requests.iter().zip(&solo) {
        assert_eq!(&r.generated, want, "id={}", r.id);
    }
}

#[test]
fn deadlines_are_surfaced_per_request() {
    let p = ParamSet::init(&host_cfg(), 44);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let mut missed = ServeRequest::new(0, vec![1, 2], 12);
    missed.deadline_s = Some(0.0);
    let fine = ServeRequest::new(1, vec![1, 2], 4);
    let rep = serve(&model, &Pool::new(2), vec![missed, fine], &ServeOptions::default()).unwrap();
    assert!(rep.requests[0].deadline_missed);
    assert!(rep.requests[0].generated.len() < 12);
    assert!(!rep.requests[1].deadline_missed);
    assert_eq!(rep.requests[1].generated.len(), 4);
    assert!(rep.requests[1].ttft_s.is_some());
}
