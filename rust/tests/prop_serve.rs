//! Serving-layer property tests (DESIGN.md §11) — all host-side, no AOT
//! artifacts required:
//!
//! - the fused dequantize kernels are **exactly** equal (bitwise, no
//!   tolerance) to `unpack()` + `gemm_bt` over every supported bit width,
//!   ragged and degenerate shapes, and jobs ∈ {1, 4};
//! - `PackedRows::unpack(Some(pool))` is bit-identical to the serial
//!   decode;
//! - greedy KV-cache decode is token-identical to the full-context
//!   matrix recompute at every step (and the final position's log-probs
//!   are bit-identical);
//! - continuous batching returns exactly the solo-decode tokens for
//!   every (batch, jobs) combination, under page-pool pressure, and
//!   surfaces missed deadlines;
//! - a prefix-cache hit decodes identically to the cold path at every
//!   (kv format, jobs, batch) combination (DESIGN.md §15);
//! - speculative decoding is token-identical to plain greedy at every
//!   (spec-k, backend) combination;
//! - refcounted prefix pages survive mid-flight retire under page
//!   pressure — every physical page returns to the pool exactly once.

use rsq::model::config::ModelConfig;
use rsq::model::ParamSet;
use rsq::quantref;
use rsq::serve::{
    greedy_decode, greedy_decode_kv, serve, serve_with_draft, token_divergence, Decoder, KvFormat,
    PackedModel, SeqKv, ServeOptions, ServeRequest,
};
use rsq::tensor::kernels::{deq_gemm_bt, deq_gemv, gemm_bt, Backend};
use rsq::tensor::pack::{PackedRows, RowGrid, PACK_BITS};
use rsq::tensor::Tensor;
use rsq::util::{Pcg, Pool};

/// RTN-quantize a random [rows, cols] matrix so it packs exactly.
fn packed(rows: usize, cols: usize, bits: u32, rng: &mut Pcg) -> PackedRows {
    let w = Tensor::randn(&[rows, cols], 1.0, rng);
    let maxq = ((1u64 << bits) - 1) as f32;
    let q = quantref::rtn(&w, maxq);
    let (scale, zero) = quantref::row_grid(&w, maxq);
    PackedRows::pack(&q, bits, &RowGrid { scale, zero }).unwrap()
}

/// Activations with exact zeros sprinkled in so the zero-skip path stays
/// live (the §10 contract the fused kernels must reproduce).
fn acts(m: usize, k: usize, rng: &mut Pcg) -> Tensor {
    let data = (0..m * k)
        .map(|_| if rng.f32() < 0.2 { 0.0 } else { rng.normal() })
        .collect();
    Tensor::from_vec(&[m, k], data)
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}");
    for (x, y) in a.data.iter().zip(&b.data) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}");
    }
}

#[test]
fn fused_kernels_match_unpack_gemm_exactly() {
    let mut rng = Pcg::new(31);
    // ragged shapes: widths that straddle byte boundaries for every bit
    // width, single rows/cols, and a tile-crossing k (> 256)
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (1, 7, 5),
        (3, 19, 33),
        (4, 64, 16),
        (2, 300, 11),
        (5, 37, 1),
    ] {
        let a = acts(m, k, &mut rng);
        for bits in PACK_BITS {
            let w = packed(n, k, bits, &mut rng);
            let want = gemm_bt(&a, &w.unpack(None), None);
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let pooled_ref = gemm_bt(&a, &w.unpack(Some(&pool)), Some(&pool));
                assert_bits_eq(&pooled_ref, &want, "reference jobs-invariance");
                for p in [None, Some(&pool)] {
                    let got = deq_gemm_bt(&a, &w, p);
                    let what = format!("deq_gemm_bt {m}x{k}x{n} bits={bits} jobs={jobs}");
                    assert_bits_eq(&got, &want, &what);
                    for i in 0..m {
                        let gv = deq_gemv(a.row(i), &w, p);
                        assert_eq!(gv, want.row(i), "deq_gemv row {i} bits={bits} jobs={jobs}");
                    }
                }
            }
        }
    }
}

#[test]
fn fused_kernels_degenerate_shapes() {
    let mut rng = Pcg::new(32);
    for bits in PACK_BITS {
        // empty activation batch
        let w = packed(6, 9, bits, &mut rng);
        let empty = Tensor::zeros(&[0, 9]);
        let out = deq_gemm_bt(&empty, &w, None);
        assert_eq!(out.shape, vec![0, 6]);
        // all-zero activations: zero-skip leaves exact +0.0 everywhere
        let zeros = Tensor::zeros(&[2, 9]);
        let out = deq_gemm_bt(&zeros, &w, Some(&Pool::new(4)));
        assert_eq!(out.data, vec![0.0; 12]);
        assert_bits_eq(&out, &gemm_bt(&zeros, &w.unpack(None), None), "zero acts");
    }
}

#[test]
fn unpack_is_pool_invariant_across_bits_and_ragged_shapes() {
    let mut rng = Pcg::new(33);
    for (rows, cols) in [(1usize, 1usize), (3, 5), (17, 31), (40, 65)] {
        for bits in PACK_BITS {
            let w = packed(rows, cols, bits, &mut rng);
            let serial = w.unpack(None);
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let what = format!("{rows}x{cols}@{bits}b j{jobs}");
                assert_bits_eq(&w.unpack(Some(&pool)), &serial, &what);
            }
        }
    }
}

fn host_cfg() -> ModelConfig {
    ModelConfig {
        name: "prop-serve".into(),
        d: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        max_seq: 40,
        batch: 2,
        seq_lens: vec![8, 40],
        ldlq_k: 64,
        ldlq_g: 4,
    }
}

#[test]
fn kv_decode_token_identical_to_full_context_recompute() {
    let p = ParamSet::init(&host_cfg(), 41);
    let prompt = [5i32, 9, 2, 14];
    for bits in PACK_BITS {
        let model = PackedModel::from_paramset_rtn(&p, bits).unwrap();
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let gen = greedy_decode(&model, &prompt, 20, Some(&pool)).unwrap();
            assert_eq!(gen.len(), 20, "bits={bits}");
            let mut seq = prompt.to_vec();
            seq.extend_from_slice(&gen);
            // full-context matrix recompute over the whole decoded
            // sequence: causality makes row i equal a fresh forward over
            // tokens 0..=i, so this checks EVERY decode step at once
            let full = model.logits_full(&seq, Some(&pool));
            for (step, &tok) in gen.iter().enumerate() {
                let row = full.row(prompt.len() + step - 1);
                assert_eq!(
                    rsq::eval::argmax(row) as i32,
                    tok,
                    "bits={bits} jobs={jobs} step={step}: KV decode diverged from recompute"
                );
            }
        }
    }
}

#[test]
fn kv_bits_32_remains_bit_identical_to_full_context_recompute() {
    // the §12 regression pin: the RowSource/attn_row refactor must have
    // changed ZERO exact-path bits — `--kv-bits 32` still reproduces the
    // full-context recompute's log-probs exactly, at jobs {1, 4}
    let p = ParamSet::init(&host_cfg(), 45);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let prompt = [5i32, 9, 2, 14];
    for jobs in [1usize, 4] {
        let pool = Pool::new(jobs);
        let gen = greedy_decode_kv(&model, &prompt, 12, KvFormat::F32, Some(&pool)).unwrap();
        assert_eq!(
            gen,
            greedy_decode(&model, &prompt, 12, Some(&pool)).unwrap(),
            "jobs={jobs}: the F32 format IS greedy_decode's path"
        );
        let mut seq = prompt.to_vec();
        seq.extend_from_slice(&gen);
        let full = model.logits_full(&seq, Some(&pool));
        let kv = SeqKv::standalone(model.cfg.layers, model.cfg.d, seq.len());
        assert_eq!(kv.format(), KvFormat::F32, "standalone stays on the exact path");
        let mut dec = Decoder::new(&model, kv);
        let mut last = Vec::new();
        for &tok in &seq {
            last = dec.step(tok, Some(&pool));
        }
        for (a, b) in last.iter().zip(full.row(seq.len() - 1)) {
            assert_eq!(a.to_bits(), b.to_bits(), "jobs={jobs}: exact-path bits changed");
        }
    }
}

#[test]
fn quantized_decode_is_deterministic_and_invariant_to_jobs_batch_and_pages() {
    // lossy but DETERMINISTIC: for kv-bits {8, 2} the decoded tokens are
    // a pure function of (model, prompt, max_new, format) — invariant to
    // jobs, batch size, and page-pool pressure
    let p = ParamSet::init(&host_cfg(), 46);
    let model = PackedModel::from_paramset_rtn(&p, 8).unwrap();
    let requests: Vec<ServeRequest> =
        (0..4u64).map(|i| ServeRequest::new(i, vec![(i as i32) + 2, 7, 11], 6)).collect();
    for fmt in [KvFormat::Linear8, KvFormat::Log2] {
        let solo: Vec<Vec<i32>> = requests
            .iter()
            .map(|r| greedy_decode_kv(&model, &r.prompt, r.max_new, fmt, None).unwrap())
            .collect();
        for (r, s) in requests.iter().zip(&solo) {
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let again =
                    greedy_decode_kv(&model, &r.prompt, r.max_new, fmt, Some(&pool)).unwrap();
                assert_eq!(&again, s, "fmt={fmt:?} id={} jobs={jobs}", r.id);
            }
        }
        for batch in [1usize, 4] {
            let opts = ServeOptions { max_batch: batch, kv: fmt, ..Default::default() };
            let rep = serve(&model, &Pool::new(2), requests.clone(), &opts).unwrap();
            for (r, want) in rep.requests.iter().zip(&solo) {
                assert_eq!(&r.generated, want, "fmt={fmt:?} id={} batch={batch}", r.id);
            }
            assert!(rep.kv_resident_bytes < rep.kv_resident_f32_bytes, "fmt={fmt:?}");
        }
        // page pressure: pool sized for exactly one worst-case
        // reservation — admissions serialize, tokens must not change
        let probe = rsq::serve::PagePool::new(model.cfg.layers, model.cfg.d, 0, 0);
        let tight = ServeOptions {
            max_batch: 4,
            pages: probe.pages_for(3 + 6),
            kv: fmt,
            ..Default::default()
        };
        let rep = serve(&model, &Pool::new(2), requests.clone(), &tight).unwrap();
        assert_eq!(rep.peak_active, 1, "fmt={fmt:?}");
        for (r, want) in rep.requests.iter().zip(&solo) {
            assert_eq!(&r.generated, want, "fmt={fmt:?} id={} under page pressure", r.id);
        }
    }
}

#[test]
fn token_divergence_is_measured_monotone_and_exactly_zero_at_32() {
    // 8-bit weights keep the weight side near-lossless so the KV format
    // is the only thing varying; short decodes bound error accumulation
    let p = ParamSet::init(&host_cfg(), 47);
    let model = PackedModel::from_paramset_rtn(&p, 8).unwrap();
    let mut div = std::collections::BTreeMap::new();
    for bits in [32u32, 8, 2] {
        let fmt = KvFormat::from_bits(bits).unwrap();
        let mut total = 0usize;
        for seed in 0..4i32 {
            let prompt = [seed + 1, 9, 2];
            let oracle = greedy_decode(&model, &prompt, 6, None).unwrap();
            let got = greedy_decode_kv(&model, &prompt, 6, fmt, None).unwrap();
            total += token_divergence(&oracle, &got);
        }
        div.insert(bits, total);
    }
    assert_eq!(div[&32], 0, "the f32 format is the oracle itself — divergence 0 by construction");
    // monotone non-increasing in kv-bits: wider KV storage never
    // diverges more (8-bit KV is near-lossless on this model, so the
    // chain stays meaningful rather than vacuous)
    assert!(
        div[&32] <= div[&8] && div[&8] <= div[&2],
        "divergence must be monotone non-increasing in kv-bits: {div:?}"
    );
    assert_eq!(div[&8], 0, "8-bit KV must not diverge on the tiny model");
}

#[test]
fn batched_serving_equals_solo_decode_and_is_jobs_invariant() {
    let p = ParamSet::init(&host_cfg(), 42);
    let model = PackedModel::from_paramset_rtn(&p, 3).unwrap();
    let requests: Vec<ServeRequest> = (0..6u64)
        .map(|i| ServeRequest::new(i, vec![(i as i32) % 11 + 1, 3, 7], 5 + (i as usize) % 4))
        .collect();
    let solo: Vec<Vec<i32>> = requests
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, None).unwrap())
        .collect();
    for batch in [1usize, 4] {
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let opts = ServeOptions { max_batch: batch, ..Default::default() };
            let rep = serve(&model, &pool, requests.clone(), &opts).unwrap();
            assert_eq!(rep.requests.len(), requests.len());
            assert!(rep.peak_active <= batch);
            assert!(rep.tokens_per_s > 0.0);
            for (r, want) in rep.requests.iter().zip(&solo) {
                assert_eq!(&r.generated, want, "id={} batch={batch} jobs={jobs}", r.id);
                assert!(!r.deadline_missed);
            }
        }
    }
}

#[test]
fn page_pool_pressure_admits_mid_flight_without_changing_tokens() {
    let p = ParamSet::init(&host_cfg(), 43);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let requests: Vec<ServeRequest> =
        (0..5u64).map(|i| ServeRequest::new(i, vec![1, 2, (i as i32) + 3], 8)).collect();
    let solo: Vec<Vec<i32>> = requests
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, None).unwrap())
        .collect();
    // pool sized for exactly one worst-case reservation: admissions must
    // serialize through retire-and-release, and tokens must not change
    let probe = rsq::serve::PagePool::new(model.cfg.layers, model.cfg.d, 0, 0);
    let pages = probe.pages_for(3 + 8);
    let opts = ServeOptions { max_batch: 4, pages, ..Default::default() };
    let rep = serve(&model, &Pool::new(2), requests, &opts).unwrap();
    assert_eq!(rep.peak_active, 1);
    for (r, want) in rep.requests.iter().zip(&solo) {
        assert_eq!(&r.generated, want, "id={}", r.id);
    }
}

#[test]
fn prefix_cache_decode_is_identical_to_cold_at_every_kv_width() {
    // the §15 determinism pin: adopting frozen prefix pages must change
    // ZERO output tokens vs the cold decode, at the exact f32 format AND
    // the lossy 8-bit codec, across jobs and batch widths. max_batch 2
    // also covers the concurrent-donor path (two identical prompts both
    // freeze their prefix; the second insert dedups and its pages still
    // come home).
    let p = ParamSet::init(&host_cfg(), 48);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let prompt = vec![3i32, 1, 4, 1, 5, 9];
    let requests: Vec<ServeRequest> =
        (0..4u64).map(|i| ServeRequest::new(i, prompt.clone(), 6)).collect();
    for fmt in [KvFormat::F32, KvFormat::Linear8] {
        for jobs in [1usize, 4] {
            for batch in [1usize, 2] {
                let pool = Pool::new(jobs);
                let base =
                    ServeOptions { max_batch: batch, page: 4, kv: fmt, ..Default::default() };
                let cold = serve(&model, &pool, requests.clone(), &base).unwrap();
                assert_eq!(cold.prefix_lookups, 0, "cache off probes nothing");
                let warm_opts = ServeOptions { prefix_cache: true, ..base };
                let warm = serve(&model, &pool, requests.clone(), &warm_opts).unwrap();
                assert!(warm.prefix_hits > 0, "fmt={fmt:?} jobs={jobs} batch={batch}");
                assert!(warm.prefill_skipped > 0, "hits must eliminate prefill forwards");
                for (c, w) in cold.requests.iter().zip(&warm.requests) {
                    assert_eq!(
                        c.generated,
                        w.generated,
                        "fmt={fmt:?} jobs={jobs} batch={batch} id={}: warm diverged from cold",
                        c.id
                    );
                }
            }
        }
    }
}

#[test]
fn speculative_decode_is_token_identical_across_spec_k_and_backends() {
    // greedy accept/correct must reproduce plain greedy token-for-token
    // at EVERY window size, on the reference backend and on simd (where
    // the row-exact verify fallback keeps batched rows bitwise equal to
    // sequential steps — tensor::kernels::Backend::fused_rows_exact)
    let p = ParamSet::init(&host_cfg(), 49);
    let mut model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let mut draft = PackedModel::from_paramset_rtn(&p, 2).unwrap();
    let requests: Vec<ServeRequest> = (0..4u64)
        .map(|i| ServeRequest::new(i, vec![(i as i32) + 2, 7, 11], 6 + (i as usize) % 3))
        .collect();
    for backend in [Backend::Reference, Backend::Simd] {
        model.set_backend(backend);
        draft.set_backend(backend);
        let plain =
            serve(&model, &Pool::new(2), requests.clone(), &ServeOptions::default()).unwrap();
        for spec_k in [1usize, 2, 3, 5] {
            let opts = ServeOptions { spec_k, ..Default::default() };
            let rep =
                serve_with_draft(&model, Some(&draft), &Pool::new(2), requests.clone(), &opts)
                    .unwrap();
            for (a, b) in plain.requests.iter().zip(&rep.requests) {
                assert_eq!(
                    a.generated,
                    b.generated,
                    "spec_k={spec_k} backend={} id={}: speculation changed the output",
                    backend.name(),
                    a.id
                );
            }
            assert!(rep.draft_accepted <= rep.draft_proposed, "spec_k={spec_k}");
            if spec_k >= 2 {
                assert!(rep.draft_proposed > 0, "spec_k={spec_k} proposed nothing");
            }
        }
    }
}

#[test]
fn refcounted_prefix_pages_survive_mid_flight_retire_under_pressure() {
    // staggered max_new makes donors retire while later admissions still
    // read the frozen prefix pages they donated, and a tight pool forces
    // admissions to serialize through release/adopt cycles. The §15
    // refcount invariant — every physical page comes home exactly once,
    // never twice — is enforced by the serve loop's end-of-run
    // free == total debug_assert (live in test builds); tokens must
    // still equal the solo decode for every request.
    let p = ParamSet::init(&host_cfg(), 50);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let shared_prompt = vec![2i32, 7, 1, 8, 2, 8];
    let mut requests: Vec<ServeRequest> = (0..5u64)
        .map(|i| ServeRequest::new(i, shared_prompt.clone(), 3 + (i as usize) * 2))
        .collect();
    // a diverging prompt at the tail exercises eviction under pressure
    requests.push(ServeRequest::new(9, vec![5, 5, 5, 5, 5, 5], 4));
    let solo: Vec<Vec<i32>> = requests
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, None).unwrap())
        .collect();
    let probe = rsq::serve::PagePool::new(model.cfg.layers, model.cfg.d, 4, 0);
    let need = |r: &ServeRequest| probe.pages_for(r.prompt.len() + r.max_new);
    let worst = requests.iter().map(need).max().unwrap();
    for slack in [0usize, 4] {
        let opts = ServeOptions {
            max_batch: 3,
            page: 4,
            pages: worst + slack,
            prefix_cache: true,
            ..Default::default()
        };
        let rep = serve(&model, &Pool::new(2), requests.clone(), &opts).unwrap();
        assert_eq!(rep.requests.len(), requests.len(), "slack={slack}");
        assert!(rep.prefix_hits > 0, "slack={slack}: staggered retires must still hit");
        for (r, want) in rep.requests.iter().zip(&solo) {
            assert_eq!(&r.generated, want, "slack={slack} id={}", r.id);
        }
    }
}

#[test]
fn deadlines_are_surfaced_per_request() {
    let p = ParamSet::init(&host_cfg(), 44);
    let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
    let mut missed = ServeRequest::new(0, vec![1, 2], 12);
    missed.deadline_s = Some(0.0);
    let fine = ServeRequest::new(1, vec![1, 2], 4);
    let rep = serve(&model, &Pool::new(2), vec![missed, fine], &ServeOptions::default()).unwrap();
    assert!(rep.requests[0].deadline_missed);
    assert!(rep.requests[0].generated.len() < 12);
    assert!(!rep.requests[1].deadline_missed);
    assert_eq!(rep.requests[1].generated.len(), 4);
    assert!(rep.requests[1].ttft_s.is_some());
}
