//! Kernel equivalence property tests (DESIGN.md §10): the pool-parallel
//! tiled `tensor::kernels` family vs the naive reference kernel
//! (`Tensor::matmul` + materialized `transpose2()`), over ragged and
//! degenerate shapes, **bit-identical** — exact equality, no tolerance —
//! and bit-invariant across jobs ∈ {1, 4}; plus blocked-vs-unblocked
//! Cholesky / triangular-inverse agreement on SPD matrices.
//!
//! This file and `tensor/` are the only sanctioned homes of
//! reference-kernel products.
//!
//! The second half pins the simd backend (DESIGN.md §13) against the
//! reference backend through the shared `common` tolerance harness —
//! ULP/relative bounds, never exact equality, because the AVX2+FMA
//! kernels reassociate their dot reductions. Those properties self-skip
//! on hosts without AVX2+FMA.

mod common;

use rsq::tensor::pack::{PackedRows, RowGrid};
use rsq::tensor::{kernels, linalg, Tensor};
use rsq::util::prop::{check, Config};
use rsq::util::{Pcg, Pool};

/// A dimension that is deliberately often degenerate: 0, 1, or ragged.
fn dim(rng: &mut Pcg, size: usize) -> usize {
    match rng.below(8) {
        0 => 0,
        1 => 1,
        _ => 2 + rng.below(size.max(1)),
    }
}

/// Random matrix with exact zeros sprinkled in, so the zero-skip path of
/// the kernels is exercised on every instance.
fn randm(r: usize, c: usize, rng: &mut Pcg) -> Tensor {
    let data = (0..r * c)
        .map(|_| if rng.f32() < 0.15 { 0.0 } else { rng.normal() })
        .collect();
    Tensor::from_vec(&[r, c], data)
}

fn pools() -> [Option<Pool>; 3] {
    [None, Some(Pool::new(1)), Some(Pool::new(4))]
}

#[test]
fn prop_gemm_bit_identical_to_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let b = randm(k, n, rng);
        let want = a.matmul(&b);
        pools().iter().all(|p| {
            let got = kernels::gemm(&a, &b, p.as_ref());
            got.shape == want.shape && got.data == want.data
        })
    });
}

#[test]
fn prop_gemm_at_bit_identical_to_transposed_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm_at", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(k, m, rng); // kernels read Aᵀ in place ...
        let b = randm(k, n, rng);
        let want = a.transpose2().matmul(&b); // ... the reference materializes it
        pools().iter().all(|p| kernels::gemm_at(&a, &b, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_gemm_bt_bit_identical_to_transposed_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm_bt", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let b = randm(n, k, rng);
        let want = a.matmul(&b.transpose2());
        pools().iter().all(|p| kernels::gemm_bt(&a, &b, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_syrk_bit_identical_to_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "syrk", |rng, size| {
        let (m, k) = (dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let want_aat = a.matmul(&a.transpose2());
        let want_ata = a.transpose2().matmul(&a);
        pools().iter().all(|p| {
            kernels::syrk(&a, p.as_ref()).data == want_aat.data
                && kernels::syrk_t(&a, p.as_ref()).data == want_ata.data
        })
    });
}

fn spd(d: usize, rng: &mut Pcg) -> Tensor {
    let a = randm(d, d + 3, rng);
    let mut h = kernels::syrk(&a, None);
    for i in 0..d {
        let v = h.at2(i, i) + d as f32;
        h.set2(i, i, v);
    }
    h
}

#[test]
fn prop_blocked_cholesky_matches_unblocked() {
    // sizes past 32 cross the factor block boundary; the blocked
    // right-looking schedule performs the reference's exact fp operation
    // sequence, so agreement is bitwise, not approximate
    let cfg = Config { cases: 24, min_size: 1, max_size: 96, ..Default::default() };
    check(cfg, "chol", |rng, size| {
        let h = spd(size, rng);
        let want = linalg::cholesky_lower(&h);
        pools().iter().all(|p| kernels::cholesky_lower(&h, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_blocked_tri_inv_matches_unblocked() {
    let cfg = Config { cases: 24, min_size: 1, max_size: 96, ..Default::default() };
    check(cfg, "tri_inv", |rng, size| {
        let l = linalg::cholesky_lower(&spd(size, rng));
        let want = linalg::tri_inv_lower(&l);
        pools().iter().all(|p| kernels::tri_inv_lower(&l, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_hinv_chain_jobs_invariant_and_correct() {
    // the full hinv_cholesky_upper chain (cholesky → tri-inv → Gram →
    // re-factor) is jobs-invariant bit for bit, and its contract
    // UᵀU·(H + damp·mean·I) ≈ I holds
    let cfg = Config { cases: 12, min_size: 2, max_size: 48, ..Default::default() };
    check(cfg, "hinv", |rng, size| {
        let d = size.max(2);
        let h = spd(d, rng);
        let serial = linalg::hinv_cholesky_upper(&h, 0.01, None);
        let pooled = linalg::hinv_cholesky_upper(&h, 0.01, Some(&Pool::new(4)));
        if serial.data != pooled.data {
            return false;
        }
        let dmean = (0..d).map(|i| h.at2(i, i)).sum::<f32>() / d as f32;
        let mut hd = h.clone();
        for i in 0..d {
            let v = hd.at2(i, i) + 0.01 * dmean;
            hd.set2(i, i, v);
        }
        let prod = kernels::gemm(&kernels::syrk_t(&serial, None), &hd, None);
        (0..d).all(|i| {
            (0..d).all(|j| {
                let want = if i == j { 1.0 } else { 0.0 };
                (prod.at2(i, j) - want).abs() < 2e-2 * d as f32
            })
        })
    });
}

#[test]
fn prop_zero_skip_contract_under_non_finite_input() {
    // the a == 0.0 zero-skip (satellite contract, DESIGN.md §10): zeros in
    // A suppress NaN/∞ from the B rows they meet, identically in the tiled
    // kernels and the naive reference — including the parallel dispatch
    let cfg = Config { cases: 24, min_size: 2, max_size: 24, ..Default::default() };
    check(cfg, "zero_skip", |rng, size| {
        let (m, k, n) = (dim(rng, size).max(1), dim(rng, size).max(2), dim(rng, size).max(1));
        let mut a = randm(m, k, rng);
        let mut b = randm(k, n, rng);
        // pick a k-index whose A column is zeroed and whose B row is poisoned
        let kk = rng.below(k);
        for i in 0..m {
            a.set2(i, kk, 0.0);
        }
        for j in 0..n {
            b.set2(kk, j, if rng.below(2) == 0 { f32::NAN } else { f32::INFINITY });
        }
        let want = a.matmul(&b);
        want.data.iter().all(|v| v.is_finite())
            && pools().iter().all(|p| {
                kernels::gemm(&a, &b, p.as_ref()).data == want.data
                    && kernels::gemm_at(&a.transpose2(), &b, p.as_ref()).data == want.data
                    && kernels::gemm_bt(&a, &b.transpose2(), p.as_ref()).data == want.data
            })
    });
}

// --------------------------------------------------------------------------
// simd backend vs reference (DESIGN.md §13) — tolerance-pinned, never exact

/// Jobs sweep for the simd properties; `None` (serial) is covered by the
/// `Pool::new(1)` cell because dispatch below `POOL_MIN_WORK` is serial.
fn simd_pools() -> [Option<Pool>; 2] {
    [Some(Pool::new(1)), Some(Pool::new(4))]
}

fn close_slice(want: &[f32], got: &[f32]) -> bool {
    want.len() == got.len()
        && want.iter().zip(got).all(|(&w, &g)| common::within_tolerance(w, g))
}

fn close(want: &Tensor, got: &Tensor) -> bool {
    want.shape == got.shape && close_slice(&want.data, &got.data)
}

/// Skip marker for hosts without AVX2+FMA: the simd dispatchers would
/// fall back to the scalar reference there, making the property vacuous.
fn simd_or_skip(name: &str) -> bool {
    let ok = kernels::simd_available();
    if !ok {
        eprintln!("{name}: host lacks x86-64 AVX2+FMA, simd property skipped");
    }
    ok
}

/// RTN-quantize a random matrix so it packs exactly (gemv test idiom).
fn packed(rows: usize, cols: usize, bits: u32, rng: &mut Pcg) -> PackedRows {
    let w = Tensor::randn(&[rows, cols], 1.0, rng);
    let maxq = ((1u64 << bits) - 1) as f32;
    let q = rsq::quantref::rtn(&w, maxq);
    let (scale, zero) = rsq::quantref::row_grid(&w, maxq);
    PackedRows::pack(&q, bits, &RowGrid { scale, zero }).unwrap()
}

#[test]
fn prop_simd_gemm_family_matches_reference_within_tolerance() {
    if !simd_or_skip("simd_gemm") {
        return;
    }
    let be = kernels::Backend::Simd;
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "simd_gemm", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let b = randm(k, n, rng);
        let at = a.transpose2();
        let bt = b.transpose2();
        simd_pools().iter().all(|p| {
            let p = p.as_ref();
            close(&kernels::gemm(&a, &b, None), &be.gemm(&a, &b, p))
                && close(&kernels::gemm_at(&at, &b, None), &be.gemm_at(&at, &b, p))
                && close(&kernels::gemm_bt(&a, &bt, None), &be.gemm_bt(&a, &bt, p))
        })
    });
}

#[test]
fn prop_simd_syrk_matches_reference_within_tolerance() {
    if !simd_or_skip("simd_syrk") {
        return;
    }
    let be = kernels::Backend::Simd;
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "simd_syrk", |rng, size| {
        let (m, k) = (dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        simd_pools().iter().all(|p| {
            let p = p.as_ref();
            close(&kernels::syrk(&a, None), &be.syrk(&a, p))
                && close(&kernels::syrk_t(&a, None), &be.syrk_t(&a, p))
        })
    });
}

#[test]
fn prop_simd_deq_kernels_match_reference_within_tolerance() {
    if !simd_or_skip("simd_deq") {
        return;
    }
    let be = kernels::Backend::Simd;
    let cfg = Config { cases: 32, max_size: 32, ..Default::default() };
    check(cfg, "simd_deq", |rng, size| {
        // every supported packed width; dims ≥ 1 because the RTN grid of
        // an empty row is undefined
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let (m, k, n) = (dim(rng, size).max(1), dim(rng, size).max(1), dim(rng, size).max(1));
        let w = packed(n, k, bits, rng);
        let a = randm(m, k, rng);
        let x = randm(1, k, rng);
        simd_pools().iter().all(|p| {
            let p = p.as_ref();
            close(&kernels::deq_gemm_bt(&a, &w, None), &be.deq_gemm_bt(&a, &w, p))
                && close_slice(&kernels::deq_gemv(&x.data, &w, None), &be.deq_gemv(&x.data, &w, p))
        })
    });
}

#[test]
fn prop_simd_dot_axpy_match_reference_within_tolerance() {
    if !simd_or_skip("simd_dot_axpy") {
        return;
    }
    let cfg = Config { cases: 48, max_size: 96, ..Default::default() };
    check(cfg, "simd_dot_axpy", |rng, size| {
        let n = dim(rng, size);
        let a = randm(1, n, rng);
        let b = randm(1, n, rng);
        let c = rng.normal();
        let rd = kernels::Backend::Reference.dot(&a.data, &b.data);
        let sd = kernels::Backend::Simd.dot(&a.data, &b.data);
        let mut ry = b.data.clone();
        let mut sy = b.data.clone();
        kernels::Backend::Reference.axpy(c, &a.data, &mut ry);
        kernels::Backend::Simd.axpy(c, &a.data, &mut sy);
        common::within_tolerance(rd, sd) && close_slice(&ry, &sy)
    });
}
