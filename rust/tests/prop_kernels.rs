//! Kernel equivalence property tests (DESIGN.md §10): the pool-parallel
//! tiled `tensor::kernels` family vs the naive reference kernel
//! (`Tensor::matmul` + materialized `transpose2()`), over ragged and
//! degenerate shapes, **bit-identical** — exact equality, no tolerance —
//! and bit-invariant across jobs ∈ {1, 4}; plus blocked-vs-unblocked
//! Cholesky / triangular-inverse agreement on SPD matrices.
//!
//! This file and `tensor/` are the only sanctioned homes of
//! reference-kernel products.

use rsq::tensor::{kernels, linalg, Tensor};
use rsq::util::prop::{check, Config};
use rsq::util::{Pcg, Pool};

/// A dimension that is deliberately often degenerate: 0, 1, or ragged.
fn dim(rng: &mut Pcg, size: usize) -> usize {
    match rng.below(8) {
        0 => 0,
        1 => 1,
        _ => 2 + rng.below(size.max(1)),
    }
}

/// Random matrix with exact zeros sprinkled in, so the zero-skip path of
/// the kernels is exercised on every instance.
fn randm(r: usize, c: usize, rng: &mut Pcg) -> Tensor {
    let data = (0..r * c)
        .map(|_| if rng.f32() < 0.15 { 0.0 } else { rng.normal() })
        .collect();
    Tensor::from_vec(&[r, c], data)
}

fn pools() -> [Option<Pool>; 3] {
    [None, Some(Pool::new(1)), Some(Pool::new(4))]
}

#[test]
fn prop_gemm_bit_identical_to_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let b = randm(k, n, rng);
        let want = a.matmul(&b);
        pools().iter().all(|p| {
            let got = kernels::gemm(&a, &b, p.as_ref());
            got.shape == want.shape && got.data == want.data
        })
    });
}

#[test]
fn prop_gemm_at_bit_identical_to_transposed_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm_at", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(k, m, rng); // kernels read Aᵀ in place ...
        let b = randm(k, n, rng);
        let want = a.transpose2().matmul(&b); // ... the reference materializes it
        pools().iter().all(|p| kernels::gemm_at(&a, &b, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_gemm_bt_bit_identical_to_transposed_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "gemm_bt", |rng, size| {
        let (m, k, n) = (dim(rng, size), dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let b = randm(n, k, rng);
        let want = a.matmul(&b.transpose2());
        pools().iter().all(|p| kernels::gemm_bt(&a, &b, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_syrk_bit_identical_to_reference() {
    check(Config { cases: 48, max_size: 40, ..Default::default() }, "syrk", |rng, size| {
        let (m, k) = (dim(rng, size), dim(rng, size));
        let a = randm(m, k, rng);
        let want_aat = a.matmul(&a.transpose2());
        let want_ata = a.transpose2().matmul(&a);
        pools().iter().all(|p| {
            kernels::syrk(&a, p.as_ref()).data == want_aat.data
                && kernels::syrk_t(&a, p.as_ref()).data == want_ata.data
        })
    });
}

fn spd(d: usize, rng: &mut Pcg) -> Tensor {
    let a = randm(d, d + 3, rng);
    let mut h = kernels::syrk(&a, None);
    for i in 0..d {
        let v = h.at2(i, i) + d as f32;
        h.set2(i, i, v);
    }
    h
}

#[test]
fn prop_blocked_cholesky_matches_unblocked() {
    // sizes past 32 cross the factor block boundary; the blocked
    // right-looking schedule performs the reference's exact fp operation
    // sequence, so agreement is bitwise, not approximate
    let cfg = Config { cases: 24, min_size: 1, max_size: 96, ..Default::default() };
    check(cfg, "chol", |rng, size| {
        let h = spd(size, rng);
        let want = linalg::cholesky_lower(&h);
        pools().iter().all(|p| kernels::cholesky_lower(&h, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_blocked_tri_inv_matches_unblocked() {
    let cfg = Config { cases: 24, min_size: 1, max_size: 96, ..Default::default() };
    check(cfg, "tri_inv", |rng, size| {
        let l = linalg::cholesky_lower(&spd(size, rng));
        let want = linalg::tri_inv_lower(&l);
        pools().iter().all(|p| kernels::tri_inv_lower(&l, p.as_ref()).data == want.data)
    });
}

#[test]
fn prop_hinv_chain_jobs_invariant_and_correct() {
    // the full hinv_cholesky_upper chain (cholesky → tri-inv → Gram →
    // re-factor) is jobs-invariant bit for bit, and its contract
    // UᵀU·(H + damp·mean·I) ≈ I holds
    let cfg = Config { cases: 12, min_size: 2, max_size: 48, ..Default::default() };
    check(cfg, "hinv", |rng, size| {
        let d = size.max(2);
        let h = spd(d, rng);
        let serial = linalg::hinv_cholesky_upper(&h, 0.01, None);
        let pooled = linalg::hinv_cholesky_upper(&h, 0.01, Some(&Pool::new(4)));
        if serial.data != pooled.data {
            return false;
        }
        let dmean = (0..d).map(|i| h.at2(i, i)).sum::<f32>() / d as f32;
        let mut hd = h.clone();
        for i in 0..d {
            let v = hd.at2(i, i) + 0.01 * dmean;
            hd.set2(i, i, v);
        }
        let prod = kernels::gemm(&kernels::syrk_t(&serial, None), &hd, None);
        (0..d).all(|i| {
            (0..d).all(|j| {
                let want = if i == j { 1.0 } else { 0.0 };
                (prod.at2(i, j) - want).abs() < 2e-2 * d as f32
            })
        })
    });
}

#[test]
fn prop_zero_skip_contract_under_non_finite_input() {
    // the a == 0.0 zero-skip (satellite contract, DESIGN.md §10): zeros in
    // A suppress NaN/∞ from the B rows they meet, identically in the tiled
    // kernels and the naive reference — including the parallel dispatch
    let cfg = Config { cases: 24, min_size: 2, max_size: 24, ..Default::default() };
    check(cfg, "zero_skip", |rng, size| {
        let (m, k, n) = (dim(rng, size).max(1), dim(rng, size).max(2), dim(rng, size).max(1));
        let mut a = randm(m, k, rng);
        let mut b = randm(k, n, rng);
        // pick a k-index whose A column is zeroed and whose B row is poisoned
        let kk = rng.below(k);
        for i in 0..m {
            a.set2(i, kk, 0.0);
        }
        for j in 0..n {
            b.set2(kk, j, if rng.below(2) == 0 { f32::NAN } else { f32::INFINITY });
        }
        let want = a.matmul(&b);
        want.data.iter().all(|v| v.is_finite())
            && pools().iter().all(|p| {
                kernels::gemm(&a, &b, p.as_ref()).data == want.data
                    && kernels::gemm_at(&a.transpose2(), &b, p.as_ref()).data == want.data
                    && kernels::gemm_bt(&a, &b.transpose2(), p.as_ref()).data == want.data
            })
    });
}
