//! End-to-end pipeline benchmarks: full quantization wall time per method,
//! the parallel scheduler's jobs=1 vs jobs=N scaling, plus the host-side
//! stages (corpus generation, rotation, checkpoint IO). The L3 side of
//! DESIGN.md §Perf.
//!
//!     cargo bench --bench bench_pipeline

use rsq::corpus::{expand_dataset, CalibSet, CorpusKind};
use rsq::model::fuse::fuse_gains;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::rotate::{rotate_params, rotation_matrix};
use rsq::model::ParamSet;
use rsq::quant::{quantize, Method, QuantOptions, SchedMode};
use rsq::runtime::Engine;
use rsq::train::train_or_load;
use rsq::util::Bench;

fn main() -> anyhow::Result<()> {
    println!("=== pipeline benchmarks (config tiny) ===");
    let eng = Engine::load("tiny")?;
    let cfg = eng.config().clone();
    let t = *cfg.seq_lens.iter().max().unwrap();
    let (mut params, _) = train_or_load(&eng, 7, 150, false)?;
    inject_outliers(&mut params, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, t, 7, 1);
    let tokens = calib.total_tokens() as u64;

    // warm the compile cache first
    quantize(&eng, &params, &calib, &QuantOptions::new(Method::Rsq, 3, t))?;

    for method in [Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq, Method::Rsq] {
        let opts = QuantOptions::new(method, 3, t);
        Bench::new(&format!("quantize/{}", method.name()))
            .samples(5)
            .throughput_elements(tokens)
            .iter(|| quantize(&eng, &params, &calib, &opts).unwrap())
            .report();
    }
    // dataset expansion (paper Sec. 4.4) adds 8x batches:
    let mut opts = QuantOptions::new(Method::Rsq, 3, t);
    opts.expansion = 8;
    Bench::new("quantize/rsq+expansion8")
        .samples(3)
        .throughput_elements(tokens * 8)
        .iter(|| quantize(&eng, &params, &calib, &opts).unwrap())
        .report();

    // scheduler scaling: identical work across jobs=1 vs jobs=4 and the
    // staged vs cross-layer-pipelined executors
    println!("\n--- scheduler scaling (rsq, jobs x sched) ---");
    let max_jobs = 4usize;
    let mut grid = Vec::new(); // [staged j1, staged j4, pipelined j1, pipelined j4]
    for mode in [SchedMode::Staged, SchedMode::Pipelined] {
        for jobs in [1usize, max_jobs] {
            let mut o = QuantOptions::new(Method::Rsq, 3, t);
            o.jobs = jobs;
            o.sched = mode;
            let mean_s = Bench::new(&format!("quantize/rsq_{}_jobs{jobs}", mode.name()))
                .samples(5)
                .throughput_elements(tokens)
                .iter(|| quantize(&eng, &params, &calib, &o).unwrap())
                .report();
            grid.push(mean_s);
        }
    }
    println!(
        "scheduler speedup jobs={max_jobs} vs jobs=1 (staged): {:.2}x ({} hardware threads)",
        grid[0] / grid[1],
        rsq::util::pool::max_parallelism()
    );
    println!(
        "barrier elimination (pipelined vs staged): {:.2}x at jobs=1, {:.2}x at jobs={max_jobs}",
        grid[0] / grid[2],
        grid[1] / grid[3]
    );
    // the determinism contract the speedups rest on (any jobs/sched
    // combination bit-identical to serial staged, DESIGN.md §5) is
    // asserted by tests/integration_pipeline.rs
    // ::parallel_scheduler_is_bit_identical_to_serial and
    // ::pipelined_executor_bit_identical_to_staged

    println!("\n--- host-side stages ---");
    Bench::new("host/corpus_generate_64x64")
        .iter(|| CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 64, 64, 1, 1))
        .report();
    Bench::new("host/dataset_expansion_m8")
        .iter(|| expand_dataset(&calib, 8))
        .report();
    let q = rotation_matrix(cfg.d, 0);
    for jobs in [1usize, 4] {
        let pool = rsq::util::Pool::new(jobs);
        Bench::new(&format!("host/fuse+rotate_all_params_j{jobs}"))
            .iter(|| {
                let mut p2 = params.clone();
                fuse_gains(&mut p2);
                rotate_params(&mut p2, &q, &pool);
                p2
            })
            .report();
    }
    Bench::new("host/codebook_e8_k1024")
        .samples(5)
        .iter(|| rsq::quant::vq::e8_codebook(1024, 0))
        .report();
    let dir = std::env::temp_dir().join("rsq_bench_ckpt.bin");
    Bench::new("host/checkpoint_save+load")
        .iter(|| {
            params.save(&dir).unwrap();
            ParamSet::load(&cfg, &dir).unwrap()
        })
        .report();

    eng.print_stats();
    Ok(())
}
