//! One timed end-to-end bench per paper table/figure driver, at minimal
//! scale (tiny config, 1 seed, few probe instances). These verify every
//! driver stays runnable and track their wall-time regressions; the
//! full-scale numbers live in the results/ JSON records (`rsq all`).
//!
//!     cargo bench --bench bench_tables

use rsq::repro;
use rsq::util::{Args, Bench};

fn mini_args(extra: &str) -> Args {
    // tiny scale so the whole bench suite completes in minutes on 1 core
    let base = "--config tiny --seeds 1 --steps 150 --calib-n 8 --calib-t 64 \
                --probe-n 8 --lc-n 8 --eval-n 8";
    Args::parse(
        format!("{base} {extra}")
            .split_whitespace()
            .map(String::from),
    )
}

fn main() -> anyhow::Result<()> {
    println!("=== table/figure driver benchmarks (tiny scale) ===");
    let runs: Vec<(&str, fn(&Args) -> anyhow::Result<()>, &str)> = vec![
        ("table1", repro::tables::table1, ""),
        ("table2", repro::tables::table2, "--configs tiny"),
        ("table3", repro::tables::table3, ""),
        ("table4", repro::tables::table4, ""),
        ("table5", repro::tables::table5, ""),
        ("table6", repro::tables::table6, ""),
        ("table7", repro::tables::table7, ""),
        ("fig2", repro::figs::fig2, ""),
        ("fig3", repro::figs::fig3, ""),
        ("fig4", repro::figs::fig4, ""),
        ("fig5", repro::figs::fig5, "--configs tiny"),
        ("fig7", repro::figs::fig7, ""),
        ("fig8", repro::figs::fig8, ""),
        ("fig9", repro::figs::fig9, ""),
        ("scores", repro::scores::dump_scores, ""),
    ];
    for (name, f, extra) in runs {
        let args = mini_args(extra);
        // silence the driver's stdout table; keep only the bench line
        let mean = Bench::new(&format!("driver/{name}"))
            .warmup(0)
            .samples(1)
            .iter(|| f(&args).unwrap())
            .mean_s();
        println!(">>> driver/{name} completed in {mean:.2}s");
    }
    Ok(())
}
