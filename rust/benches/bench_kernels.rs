//! Micro-benchmarks for every AOT compute module (tiny + small configs) —
//! the L1/L2 side of DESIGN.md §Perf. Criterion-style output via the
//! hand-rolled harness (criterion is not in the offline vendor set).
//!
//!     cargo bench --bench bench_kernels

use rsq::model::ParamSet;
use rsq::runtime::{self, Engine};
use rsq::tensor::{kernels, Tensor};
use rsq::util::{Bench, Pcg, Pool};

fn bench_config(config: &str) -> anyhow::Result<()> {
    let eng = Engine::load(config)?;
    let cfg = eng.config().clone();
    let t = *cfg.seq_lens.iter().max().unwrap();
    let p = ParamSet::init(&cfg, 0);
    let mut rng = Pcg::new(0);
    println!("--- config {config}: d={} ff={} T={t} B={} ---", cfg.d, cfg.ff, cfg.batch);

    // embed
    let tokens: Vec<Vec<i32>> = (0..cfg.batch)
        .map(|b| (0..t).map(|i| ((b + i * 31) % cfg.vocab) as i32).collect())
        .collect();
    let tl = runtime::tokens_literal(&tokens, t)?;
    let emb_ins = vec![
        tl.clone(),
        runtime::tensor_literal(&p.tensors[0])?,
        runtime::tensor_literal(&p.tensors[1])?,
    ];
    Bench::new(&format!("{config}/embed_t{t}"))
        .iter(|| eng.exec(&format!("embed_t{t}"), &emb_ins).unwrap())
        .report();
    let z = eng.exec(&format!("embed_t{t}"), &emb_ins)?.into_iter().next().unwrap();

    // layer_fwd (with capture streams + scores)
    let mut layer_ins = vec![z];
    for k in 0..9 {
        layer_ins.push(runtime::tensor_literal(&p.tensors[2 + k])?);
    }
    let flops = 2.0
        * (cfg.batch * t) as f64
        * (4.0 * (cfg.d * cfg.d) as f64 + 3.0 * (cfg.d * cfg.ff) as f64);
    let s = Bench::new(&format!("{config}/layer_fwd_t{t}"))
        .iter(|| eng.exec(&format!("layer_fwd_t{t}"), &layer_ins).unwrap())
        .report();
    println!("    ~ {:.2} GFLOP/s (projection matmuls only)", flops / s / 1e9);
    let outs = eng.exec(&format!("layer_fwd_t{t}"), &layer_ins)?;

    // hessian accumulation (pallas kernel)
    let r = runtime::tensor_literal(&Tensor::ones(&[cfg.batch, t]))?;
    let hess_ins = vec![outs[1].clone(), r.clone()];
    let hbytes = (cfg.batch * t * cfg.d * 4) as u64;
    Bench::new(&format!("{config}/hess_d_t{t}"))
        .throughput_bytes(hbytes)
        .iter(|| eng.exec(&format!("hess_d_t{t}"), &hess_ins).unwrap())
        .report();
    let hess_ff_ins = vec![outs[4].clone(), r];
    Bench::new(&format!("{config}/hess_ff_t{t}"))
        .throughput_bytes((cfg.batch * t * cfg.ff * 4) as u64)
        .iter(|| eng.exec(&format!("hess_ff_t{t}"), &hess_ff_ins).unwrap())
        .report();

    // gptq / rtn / ldlq solvers at the (d, d) shape
    let w = Tensor::randn(&[cfg.d, cfg.d], 0.1, &mut rng);
    let h = runtime::literal_tensor(&eng.exec(&format!("hess_d_t{t}"), &hess_ins)?[0])?;
    let gptq_ins = vec![
        runtime::tensor_literal(&w)?,
        runtime::tensor_literal(&h)?,
        runtime::scalar_literal(7.0),
        runtime::scalar_literal(0.01),
    ];
    Bench::new(&format!("{config}/gptq_{0}x{0}", cfg.d))
        .throughput_elements((cfg.d * cfg.d) as u64)
        .iter(|| eng.exec(&format!("gptq_{0}x{0}", cfg.d), &gptq_ins).unwrap())
        .report();
    let rtn_ins = vec![runtime::tensor_literal(&w)?, runtime::scalar_literal(7.0)];
    Bench::new(&format!("{config}/rtn_{0}x{0}", cfg.d))
        .throughput_elements((cfg.d * cfg.d) as u64)
        .iter(|| eng.exec(&format!("rtn_{0}x{0}", cfg.d), &rtn_ins).unwrap())
        .report();
    let cb = rsq::quant::vq::e8_codebook(cfg.ldlq_k, 0);
    let ldlq_ins = vec![
        runtime::tensor_literal(&w)?,
        runtime::tensor_literal(&h)?,
        runtime::tensor_literal(&cb)?,
        runtime::scalar_literal(0.01),
    ];
    Bench::new(&format!("{config}/ldlq_{0}x{0}", cfg.d))
        .throughput_elements((cfg.d * cfg.d) as u64)
        .iter(|| eng.exec(&format!("ldlq_{0}x{0}", cfg.d), &ldlq_ins).unwrap())
        .report();

    // host-side reference GPTQ for comparison (L3 fallback path)
    Bench::new(&format!("{config}/gptq_rust_ref_{0}x{0}", cfg.d))
        .samples(5)
        .iter(|| rsq::quantref::gptq(&w, &runtime::literal_tensor(&gptq_ins[1]).unwrap(), 7.0, 0.01))
        .report();
    Ok(())
}

/// The host kernel grid (DESIGN.md §10): every `tensor::kernels` entry
/// point at representative sizes × jobs ∈ {1, 4}, the kernel-level perf
/// baseline this PR onward. Runs without the AOT artifact set.
fn bench_host_kernels() {
    println!("--- host kernel grid (tensor::kernels, sizes x jobs) ---");
    println!(
        "    pool dispatch min-work threshold: POOL_MIN_WORK = {} work units \
         (smaller shapes run serial, skipping task-claim overhead)",
        kernels::POOL_MIN_WORK
    );
    let mut rng = Pcg::new(42);
    for d in [64usize, 128, 256] {
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let b = Tensor::randn(&[d, d], 1.0, &mut rng);
        let flops = 2.0 * (d * d * d) as f64;
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let p = Some(&pool);
            let s = Bench::new(&format!("host/gemm_{d}x{d}_j{jobs}"))
                .iter(|| kernels::gemm(&a, &b, p))
                .report();
            println!("    ~ {:.2} GFLOP/s", flops / s / 1e9);
            Bench::new(&format!("host/gemm_at_{d}x{d}_j{jobs}"))
                .iter(|| kernels::gemm_at(&a, &b, p))
                .report();
            Bench::new(&format!("host/gemm_bt_{d}x{d}_j{jobs}"))
                .iter(|| kernels::gemm_bt(&a, &b, p))
                .report();
            Bench::new(&format!("host/syrk_t_{d}x{d}_j{jobs}"))
                .iter(|| kernels::syrk_t(&a, p))
                .report();
            let spd = {
                let mut h = kernels::syrk(&a, p);
                for i in 0..d {
                    let v = h.at2(i, i) + d as f32;
                    h.set2(i, i, v);
                }
                h
            };
            Bench::new(&format!("host/cholesky_{d}x{d}_j{jobs}"))
                .samples(5)
                .iter(|| kernels::cholesky_lower(&spd, p))
                .report();
            let lf = kernels::cholesky_lower(&spd, p);
            Bench::new(&format!("host/tri_inv_{d}x{d}_j{jobs}"))
                .samples(5)
                .iter(|| kernels::tri_inv_lower(&lf, p))
                .report();
        }
    }
}

/// Backend comparison grid (DESIGN.md §13): the GEMM family and the
/// serving fused-decode kernels through `Backend::Reference` vs
/// `Backend::Simd`, with per-shape speedup. simd is tolerance-pinned
/// against reference (tests/prop_kernels); this grid only times it.
fn bench_backends() {
    use rsq::tensor::kernels::Backend;
    println!("--- backend grid (reference vs simd, DESIGN.md 13) ---");
    if !kernels::simd_available() {
        println!("    simd backend unavailable (needs x86-64 AVX2+FMA); grid skipped");
        return;
    }
    fn pair(label: &str, mut f: impl FnMut(Backend)) {
        let r = Bench::new(&format!("backend/{label}_ref")).iter(|| f(Backend::Reference)).report();
        let s = Bench::new(&format!("backend/{label}_simd")).iter(|| f(Backend::Simd)).report();
        println!("    {label}: simd speedup {:.2}x", r / s.max(1e-12));
    }
    let mut rng = Pcg::new(7);
    let pool = Pool::new(4);
    let p = Some(&pool);
    for d in [64usize, 128, 256] {
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let b = Tensor::randn(&[d, d], 1.0, &mut rng);
        pair(&format!("gemm_{d}x{d}"), |be| {
            be.gemm(&a, &b, p);
        });
        pair(&format!("gemm_bt_{d}x{d}"), |be| {
            be.gemm_bt(&a, &b, p);
        });
        pair(&format!("syrk_t_{d}x{d}"), |be| {
            be.syrk_t(&a, p);
        });
    }
    // fused-decode shapes: 3-bit RTN-packed weights, one activation row
    for n in [256usize, 512] {
        let w = Tensor::randn(&[n, n], 1.0, &mut rng);
        let q = rsq::quantref::rtn(&w, 7.0);
        let (scale, zero) = rsq::quantref::row_grid(&w, 7.0);
        let grid = rsq::tensor::pack::RowGrid { scale, zero };
        let packed =
            rsq::tensor::pack::PackedRows::pack(&q, 3, &grid).expect("rtn output packs exactly");
        let x = Tensor::randn(&[1, n], 1.0, &mut rng);
        pair(&format!("deq_gemv_{n}x{n}"), |be| {
            be.deq_gemv(&x.data, &packed, p);
        });
    }
}

/// Observability off-path cost (DESIGN.md §16): a disabled span is one
/// relaxed atomic load and a branch, and an instrumented kernel must
/// time the same with the tracer off as it always did. Runs LAST:
/// enabling the tracer is monotonic and process-global, so everything
/// after `trace::enable()` records — the disabled-path rows above it
/// are only honest while nothing has enabled it yet.
fn bench_obs_overhead() {
    use rsq::obs::trace;
    println!("--- observability overhead (disabled vs enabled, DESIGN.md 16) ---");
    assert!(!trace::on(), "obs bench must run before anything enables the tracer");
    let mut rng = Pcg::new(11);
    let d = 64usize;
    let a = Tensor::randn(&[d, d], 1.0, &mut rng);
    let b = Tensor::randn(&[d, d], 1.0, &mut rng);
    Bench::new("obs/span_disabled")
        .iter(|| trace::span("bench", "obs_bench_probe"))
        .report();
    let off = Bench::new(&format!("obs/gemm_{d}x{d}_trace_off"))
        .iter(|| kernels::gemm(&a, &b, None))
        .report();
    trace::enable();
    let on = Bench::new(&format!("obs/gemm_{d}x{d}_trace_on"))
        .iter(|| kernels::gemm(&a, &b, None))
        .report();
    println!("    traced/untraced wall ratio: {:.3} (one kernel span per call)", on / off.max(1e-12));
    // drain what the traced leg recorded instead of leaving it in TLS
    let n = trace::take_events().len();
    println!("    traced leg recorded {n} events");
}

fn main() -> anyhow::Result<()> {
    println!("=== kernel/module micro-benchmarks ===");
    bench_host_kernels();
    bench_backends();
    for config in ["tiny", "small"] {
        bench_config(config)?;
    }
    bench_obs_overhead();
    Ok(())
}
