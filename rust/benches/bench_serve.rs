//! Serving-layer benchmarks (DESIGN.md §11) — the tokens/s baseline for
//! the packed-domain decode path. Runs **without** AOT artifacts on disk:
//! the model is built host-side (RTN quantize + pack at each bit width),
//! exactly like `rsq serve-bench`'s synthetic mode.
//!
//!     cargo bench --bench bench_serve
//!
//! Grid: batch × context × jobs × bits, reporting greedy-decode tokens/s
//! through the continuous-batching scheduler plus, per bit width, the
//! packed-vs-unpacked resident-bytes ratio — the deployment memory win
//! the packed-domain kernels preserve at decode time. A kv-bits axis
//! (DESIGN.md §12) then sweeps `--kv-bits {32,8,2}` KV storage under a
//! shared byte budget, reporting the KV resident-bytes ratio and greedy
//! token divergence vs the f32 oracle — and **asserts** the per-cell
//! prompt-RNG re-seed holds across the kv axis (every kv cell decodes
//! identical requests), the invariant that keeps rows comparable.
//! A final §15 section times shared-prefix traffic through the prefix
//! cache and speculative decoding against a 2-bit self-draft, asserting
//! both leave the greedy tokens untouched (the determinism contract).

use rsq::model::ParamSet;
use rsq::serve::{
    bench_model_config, greedy_decode, serve, serve_with_draft, token_divergence, KvFormat,
    PackedModel, PagePool, ServeOptions, ServeRequest, KV_BITS,
};
use rsq::tensor::kernels::{deq_gemv, gemm_bt};
use rsq::tensor::pack::PACK_BITS;
use rsq::tensor::Tensor;
use rsq::util::{Bench, Pcg, Pool};

/// The fused-kernel micro grid: dequant-GEMV vs unpack()+gemm at a
/// serving projection shape (the ff × d up-projection).
fn bench_fused_kernels() {
    println!("--- fused dequant-GEMV vs unpack()+gemm (128x64 projection) ---");
    let mut rng = Pcg::new(7);
    let (n, k) = (128usize, 64usize);
    let w = Tensor::randn(&[n, k], 1.0, &mut rng);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let xt = Tensor::from_vec(&[1, k], x.clone());
    for bits in PACK_BITS {
        let maxq = ((1u64 << bits) - 1) as f32;
        let q = rsq::quantref::rtn(&w, maxq);
        let (scale, zero) = rsq::quantref::row_grid(&w, maxq);
        let packed = rsq::tensor::pack::PackedRows::pack(
            &q,
            bits,
            &rsq::tensor::pack::RowGrid { scale, zero },
        )
        .unwrap();
        let dense = packed.unpack(None);
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            Bench::new(&format!("serve/deq_gemv_{bits}b_j{jobs}"))
                .samples(20)
                .iter(|| deq_gemv(&x, &packed, Some(&pool)))
                .report();
            Bench::new(&format!("serve/unpack_gemm_{bits}b_j{jobs}"))
                .samples(20)
                .iter(|| gemm_bt(&xt, &packed.unpack(Some(&pool)), Some(&pool)))
                .report();
        }
        // the amortized comparison point: gemm over an already-dense W
        Bench::new(&format!("serve/dense_gemm_{bits}b"))
            .samples(20)
            .iter(|| gemm_bt(&xt, &dense, None))
            .report();
    }
}

fn main() -> anyhow::Result<()> {
    println!("=== serving-layer benchmarks (host-side, no artifacts needed) ===");
    // the same synthetic config `rsq serve-bench` builds, so the grids
    // stay comparable
    let cfg = bench_model_config();
    let p = ParamSet::init(&cfg, 3);
    bench_fused_kernels();

    println!("--- serve grid: batch x context x jobs x bits ---");
    for bits in PACK_BITS {
        let model = PackedModel::from_paramset_rtn(&p, bits)?;
        let (packed_b, dense_b) = model.resident_bytes();
        println!(
            "bits={bits}: resident {packed_b} B packed vs {dense_b} B f32 \
             ({:.2}x smaller, {} packed weights)",
            dense_b as f64 / packed_b as f64,
            model.packed_weights()
        );
        for ctx in [32usize, 64] {
            for batch in [1usize, 4] {
                for jobs in [1usize, 4] {
                    let pool = Pool::new(jobs);
                    let prompt_len = 4usize;
                    // re-seeded per cell: every cell decodes the same
                    // prompts, so rows are comparable along any axis
                    let mut rng = Pcg::new(11);
                    let requests: Vec<ServeRequest> = (0..batch as u64)
                        .map(|id| {
                            let prompt =
                                (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
                            ServeRequest::new(id, prompt, ctx - prompt_len)
                        })
                        .collect();
                    let opts = ServeOptions { max_batch: batch, ..Default::default() };
                    let mut tokens = 0usize;
                    let s = Bench::new(&format!(
                        "serve/decode_{bits}b_ctx{ctx}_b{batch}_j{jobs}"
                    ))
                    .warmup(1)
                    .samples(3)
                    .iter(|| {
                        let rep = serve(&model, &pool, requests.clone(), &opts).unwrap();
                        tokens = rep.generated_tokens;
                        rep
                    })
                    .report();
                    println!("    ~ {:.1} tok/s ({tokens} tokens)", tokens as f64 / s);
                }
            }
        }
    }

    println!("--- kv-bits axis: KV storage width under a shared byte budget ---");
    let model = PackedModel::from_paramset_rtn(&p, 4)?;
    let (ctx, batch, prompt_len) = (64usize, 4usize, 4usize);
    let max_new = ctx - prompt_len;
    let pool = Pool::new(4);
    // budget: two f32 worst-case reservations, so narrower KV formats
    // surface their admission gains as higher peak occupancy
    let probe = PagePool::new(cfg.layers, cfg.d, 0, 0);
    let budget = 2 * probe.pages_for(ctx) * probe.page_bytes_f32();
    let cell_requests = || -> Vec<ServeRequest> {
        // re-seeded per cell — the same pattern as the grid above
        let mut rng = Pcg::new(11);
        (0..batch as u64)
            .map(|id| {
                let prompt = (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
                ServeRequest::new(id, prompt, max_new)
            })
            .collect()
    };
    let baseline = cell_requests();
    let oracle: Vec<Vec<i32>> = baseline
        .iter()
        .map(|r| greedy_decode(&model, &r.prompt, r.max_new, Some(&pool)))
        .collect::<anyhow::Result<_>>()?;
    for bits in KV_BITS {
        let kv = KvFormat::from_bits(bits).expect("KV_BITS entries all parse");
        let requests = cell_requests();
        // the satellite invariant: the per-cell RNG re-seed must hold
        // across the kv axis too, or rows stop being comparable
        assert_eq!(
            requests, baseline,
            "kv-bits={bits}: per-cell prompt-RNG re-seed broke across the kv axis"
        );
        let opts = ServeOptions { max_batch: batch, pool_bytes: budget, kv, ..Default::default() };
        let mut tokens = 0usize;
        let mut divergence = 0usize;
        let mut resident = (0usize, 0usize);
        let s = Bench::new(&format!("serve/decode_kv{bits}_ctx{ctx}_b{batch}"))
            .warmup(1)
            .samples(3)
            .iter(|| {
                let rep = serve(&model, &pool, requests.clone(), &opts).unwrap();
                tokens = rep.generated_tokens;
                divergence = rep
                    .requests
                    .iter()
                    .zip(&oracle)
                    .map(|(r, o)| token_divergence(o, &r.generated))
                    .sum();
                resident = (rep.kv_resident_bytes, rep.kv_resident_f32_bytes);
                rep
            })
            .report();
        assert!(bits != 32 || divergence == 0, "kv-bits 32 is the oracle itself");
        println!(
            "    ~ {:.1} tok/s  kv {} B vs {} B f32 ({:.2}x), divergence {divergence}",
            tokens as f64 / s,
            resident.0,
            resident.1,
            resident.1 as f64 / (resident.0.max(1)) as f64
        );
    }

    println!("--- prefix cache + speculative decoding (DESIGN.md §15) ---");
    // shared-prefix traffic through one slot: every admission after the
    // first adopts the pages the first request donated — zero prefill
    // forwards for the shared span
    let shared: Vec<ServeRequest> = (0..4u64)
        .map(|id| ServeRequest::new(id, baseline[0].prompt.clone(), max_new))
        .collect();
    let solo = greedy_decode(&model, &shared[0].prompt, max_new, Some(&pool))?;
    // page = 2 puts a page boundary inside the 4-token prompt — the
    // cache keys on page-aligned prefixes, so the default 16-position
    // pages would never produce a donatable boundary here
    let popts = ServeOptions { max_batch: 1, page: 2, prefix_cache: true, ..Default::default() };
    let mut hit_stats = (0usize, 0usize, 0usize);
    let s = Bench::new("serve/prefix_cache_shared_b1")
        .warmup(1)
        .samples(3)
        .iter(|| {
            let rep = serve(&model, &pool, shared.clone(), &popts).unwrap();
            hit_stats = (rep.prefix_hits, rep.prefix_lookups, rep.prefill_skipped);
            // the §15 determinism contract: hits change zero tokens
            for r in &rep.requests {
                assert_eq!(r.generated, solo, "prefix hit changed the greedy tokens");
            }
            rep
        })
        .report();
    assert!(hit_stats.0 > 0, "shared-prefix traffic must hit the cache");
    println!(
        "    ~ {:.1} batches/s  hits {}/{} ({} prefill forwards skipped)",
        1.0 / s,
        hit_stats.0,
        hit_stats.1,
        hit_stats.2
    );
    // speculative self-decoding: a 2-bit RTN packing of the same weights
    // drafts spec-k-token windows the 4-bit target verifies in one
    // batched forward each
    let draft = PackedModel::from_paramset_rtn(&p, 2)?;
    for spec_k in [2usize, 4] {
        let requests = cell_requests();
        let sopts = ServeOptions { max_batch: batch, spec_k, ..Default::default() };
        let mut acc = (0usize, 0usize);
        let s = Bench::new(&format!("serve/spec_k{spec_k}_b{batch}"))
            .warmup(1)
            .samples(3)
            .iter(|| {
                let rep = serve_with_draft(&model, Some(&draft), &pool, requests.clone(), &sopts)
                    .unwrap();
                acc = (rep.draft_accepted, rep.draft_proposed);
                // accept/correct reproduces plain greedy token-for-token
                for (r, o) in rep.requests.iter().zip(&oracle) {
                    assert_eq!(&r.generated, o, "speculation changed the greedy tokens");
                }
                rep
            })
            .report();
        println!(
            "    ~ spec-k={spec_k}: accepted {}/{} drafts (rate {:.2})",
            acc.0,
            acc.1,
            acc.0 as f64 / (acc.1.max(1)) as f64
        );
    }
    Ok(())
}
