# Allow `pytest python/tests/` from the repo root: the test modules import
# the `compile` package which lives under python/.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
